//! The paper's three evaluation scenarios as network builders (Fig. 4
//! topology: 8 workers behind a switch; bottlenecks created by shaping
//! links, competing traffic by iperf-like generators).

use crate::netsim::link::LinkConfig;
use crate::netsim::schedule::{mbps, BandwidthSchedule};
use crate::netsim::topology::StarTopology;
use crate::netsim::traffic::{CompetingTraffic, LinkRef, TrafficPattern};
use crate::netsim::{NetSim, NetSimConfig, SimTime};

/// Per-link propagation delay used across experiments (WAN-ish; gives the
/// BDP scale the paper's Algorithm 1 operates against).
pub const PROP_DELAY_MS: u64 = 10;

/// Shared runner options.
#[derive(Clone, Debug)]
pub struct RunOpts {
    /// Scale experiment horizons down 10× (benches / CI).
    pub fast: bool,
    /// Where to drop CSV curves (None = tables only).
    pub out_dir: Option<std::path::PathBuf>,
    pub seed: u64,
    pub n_workers: usize,
    /// Full-fidelity compression cadence (steps).
    pub fidelity_every: usize,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            fast: false,
            out_dir: None,
            seed: 42,
            n_workers: 8,
            fidelity_every: 250,
        }
    }
}

impl RunOpts {
    pub fn horizon(&self, secs: f64) -> f64 {
        if self.fast {
            secs / 10.0
        } else {
            secs
        }
    }
}

/// Scenario builders.
pub struct Scenario;

impl Scenario {
    /// Scenario 1: all links shaped to a static bottleneck bandwidth.
    pub fn static_bottleneck(n_workers: usize, bw_bps: f64) -> NetSim {
        NetSim::quiet(StarTopology::constant(
            n_workers,
            bw_bps,
            SimTime::from_millis(PROP_DELAY_MS),
        ))
    }

    /// Scenario 2 (Fig. 7): bandwidth degrades from 2000 to 200 Mbps in
    /// −200 Mbps steps, one step every `step_secs`.
    pub fn degrading(n_workers: usize, step_secs: f64) -> NetSim {
        let sched = BandwidthSchedule::stepped(
            mbps(2000.0),
            mbps(200.0),
            -mbps(200.0),
            SimTime::from_secs_f64(step_secs),
        );
        let cfg = LinkConfig::new(sched, SimTime::from_millis(PROP_DELAY_MS));
        NetSim::quiet(StarTopology::uniform(n_workers, cfg))
    }

    /// Scenario 3 (Fig. 8): static 2000 Mbps links with iperf-like on/off
    /// competing flows preempting two workers' links (the paper runs
    /// multiple iperf3 processes between nodes).
    pub fn fluctuating(n_workers: usize, seed: u64) -> NetSim {
        let cfg = LinkConfig::new(
            BandwidthSchedule::constant(mbps(2000.0)),
            SimTime::from_millis(PROP_DELAY_MS),
        );
        let topology = StarTopology::uniform(n_workers, cfg);
        // Two bursty flows with different periods → beating interference,
        // plus a Poisson mice mix.
        let traffic = vec![
            CompetingTraffic::new(
                TrafficPattern::OnOff {
                    on: SimTime::from_secs_f64(45.0),
                    off: SimTime::from_secs_f64(35.0),
                    rate_bps: mbps(1500.0),
                    tick: SimTime::from_millis(20),
                },
                vec![LinkRef::Up(0), LinkRef::Down(0)],
                seed ^ 0x1111,
            ),
            CompetingTraffic::new(
                TrafficPattern::OnOff {
                    on: SimTime::from_secs_f64(30.0),
                    off: SimTime::from_secs_f64(50.0),
                    rate_bps: mbps(1200.0),
                    tick: SimTime::from_millis(20),
                },
                vec![LinkRef::Up(1), LinkRef::Down(1)],
                seed ^ 0x2222,
            )
            .starting_at(SimTime::from_secs_f64(20.0)),
            CompetingTraffic::new(
                TrafficPattern::Poisson {
                    msgs_per_sec: 50.0,
                    mean_msg_bytes: 200_000.0,
                },
                vec![LinkRef::Up(2)],
                seed ^ 0x3333,
            ),
        ];
        NetSim::new(NetSimConfig { topology, traffic })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_scenario_shapes_all_links() {
        let sim = Scenario::static_bottleneck(8, mbps(200.0));
        assert_eq!(sim.topology.n_workers(), 8);
        for l in &sim.topology.uplinks {
            assert_eq!(l.true_rate_at(SimTime::ZERO), mbps(200.0));
        }
    }

    #[test]
    fn degrading_scenario_descends() {
        let sim = Scenario::degrading(8, 60.0);
        let l = &sim.topology.uplinks[0];
        assert_eq!(l.true_rate_at(SimTime::ZERO), mbps(2000.0));
        assert_eq!(
            l.true_rate_at(SimTime::from_secs_f64(60.0 * 9.0 + 1.0)),
            mbps(200.0)
        );
    }

    #[test]
    fn fluctuating_scenario_has_traffic() {
        let mut sim = Scenario::fluctuating(8, 1);
        sim.advance_to(SimTime::from_secs_f64(120.0));
        let delivered = sim.topology.total_delivered_bytes();
        assert!(delivered > 1_000_000, "no competing traffic flowed: {delivered}");
    }

    #[test]
    fn fast_opt_scales_horizon() {
        let mut o = RunOpts::default();
        assert_eq!(o.horizon(1000.0), 1000.0);
        o.fast = true;
        assert_eq!(o.horizon(1000.0), 100.0);
    }
}
