//! Result tables: markdown rendering (what the CLI prints) and CSV export
//! (what figures are plotted from).

use std::io::Write;
use std::path::Path;

/// A simple result table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as GitHub-flavored markdown with aligned columns.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (cell, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {cell:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

/// Format helpers shared by the runners.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn opt_time(x: Option<f64>) -> String {
    match x {
        Some(t) => format!("{t:.0}"),
        None => "N/A".to_string(),
    }
}

/// Write a set of (x, y) series as a long-format CSV: `series,x,y`.
pub fn write_series_csv(
    path: &Path,
    xlabel: &str,
    ylabel: &str,
    series: &[(String, Vec<(f64, f64)>)],
) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "series,{xlabel},{ylabel}")?;
    for (name, points) in series {
        for (x, y) in points {
            writeln!(f, "{name},{x},{y}")?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Throughput"]);
        t.row(vec!["NetSenseML".into(), "642.90".into()]);
        t.row(vec!["AllReduce".into(), "42.20".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| NetSenseML |"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_export() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let p = std::env::temp_dir().join("netsense_table_test.csv");
        t.write_csv(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "a,b\n1,2\n");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn series_csv() {
        let p = std::env::temp_dir().join("netsense_series_test.csv");
        write_series_csv(
            &p,
            "t",
            "acc",
            &[("ns".to_string(), vec![(1.0, 2.0), (3.0, 4.0)])],
        )
        .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("ns,1,2"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(f1(1.25), "1.2");
        assert_eq!(f2(1.256), "1.26");
        assert_eq!(opt_time(None), "N/A");
        assert_eq!(opt_time(Some(1575.4)), "1575");
    }
}
