//! Figures 5 & 6 — Time-to-accuracy curves per bottleneck bandwidth.
//!
//! Each figure panel is one bandwidth; each series is one method's
//! accuracy-vs-time trajectory. The runner prints a TTA summary table
//! (time for each method to reach the panel's target accuracy) and writes
//! the full curves as CSV for plotting.

use super::report::{opt_time, write_series_csv, Table};
use super::scenario::{RunOpts, Scenario};
use crate::coordinator::{run_sim_training, SimTrainConfig, SyncStrategy};
use crate::netsim::schedule::{gbps, mbps};
use crate::trainer::metrics::TrainLog;
use crate::trainer::models::PaperModel;

/// One panel's data: the three methods' logs.
pub struct TtaPanel {
    pub bw_label: String,
    pub target_acc: f64,
    pub logs: Vec<TrainLog>,
}

fn run_panel(
    model: &'static PaperModel,
    bw_bps: f64,
    bw_label: &str,
    horizon: f64,
    opts: &RunOpts,
) -> TtaPanel {
    let mut logs = Vec::new();
    for strategy in [
        SyncStrategy::NetSense,
        SyncStrategy::AllReduce,
        SyncStrategy::TopK(0.1),
    ] {
        let mut config = SimTrainConfig::new(model, strategy);
        config.n_workers = opts.n_workers;
        config.max_vtime_s = horizon;
        config.fidelity_every = opts.fidelity_every;
        config.seed = opts.seed;
        let mut sim = Scenario::static_bottleneck(opts.n_workers, bw_bps);
        logs.push(run_sim_training(&config, &mut sim).expect("sim sync decodes its own frames"));
    }
    // Target accuracy: 95% of NetSenseML's best (a reachable common bar).
    let target_acc = logs[0].best_acc() * 0.95;
    TtaPanel {
        bw_label: bw_label.to_string(),
        target_acc,
        logs,
    }
}

fn build_fig(
    name: &str,
    title: &str,
    model: &'static PaperModel,
    points: &[(f64, &str)],
    horizon: f64,
    opts: &RunOpts,
) -> (Table, Vec<TtaPanel>) {
    let mut table = Table::new(
        title,
        &["Bandwidth", "Target Acc (%)", "Method", "TTA (s)", "Best Acc (%)"],
    );
    let mut panels = Vec::new();
    for &(bw, label) in points {
        let panel = run_panel(model, bw, label, horizon, opts);
        for log in &panel.logs {
            table.row(vec![
                label.to_string(),
                format!("{:.1}", panel.target_acc),
                log.method.clone(),
                opt_time(log.time_to_accuracy(panel.target_acc)),
                format!("{:.2}", log.best_acc()),
            ]);
        }
        if let Some(dir) = &opts.out_dir {
            std::fs::create_dir_all(dir).ok();
            let series: Vec<(String, Vec<(f64, f64)>)> = panel
                .logs
                .iter()
                .map(|l| (l.method.clone(), l.acc_curve(400)))
                .collect();
            write_series_csv(
                &dir.join(format!("{name}_{label}.csv")),
                "vtime_s",
                "accuracy",
                &series,
            )
            .ok();
        }
        panels.push(panel);
    }
    (table, panels)
}

/// Fig. 5: ResNet18 TTA at 200/500/800 Mbps.
pub fn fig5(opts: &RunOpts) -> (Table, Vec<TtaPanel>) {
    build_fig(
        "fig5",
        "Fig 5: Time-to-accuracy, ResNet18 (200/500/800 Mbps)",
        PaperModel::by_name("resnet18").unwrap(),
        &[
            (mbps(200.0), "200Mbps"),
            (mbps(500.0), "500Mbps"),
            (mbps(800.0), "800Mbps"),
        ],
        opts.horizon(2500.0),
        opts,
    )
}

/// Fig. 6: VGG16 TTA at 2.5/5/10 Gbps.
pub fn fig6(opts: &RunOpts) -> (Table, Vec<TtaPanel>) {
    build_fig(
        "fig6",
        "Fig 6: Time-to-accuracy, VGG16 (2.5/5/10 Gbps)",
        PaperModel::by_name("vgg16").unwrap(),
        &[
            (gbps(2.5), "2.5Gbps"),
            (gbps(5.0), "5Gbps"),
            (gbps(10.0), "10Gbps"),
        ],
        opts.horizon(2800.0),
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_netsense_reaches_target_first() {
        let opts = RunOpts {
            fast: true,
            fidelity_every: 0,
            ..Default::default()
        };
        let (_, panels) = fig5(&opts);
        assert_eq!(panels.len(), 3);
        for panel in &panels {
            let ns = &panel.logs[0];
            let ns_tta = ns.time_to_accuracy(panel.target_acc);
            assert!(ns_tta.is_some(), "{}: NetSense never hit target", panel.bw_label);
            for other in &panel.logs[1..] {
                match other.time_to_accuracy(panel.target_acc) {
                    None => {} // baseline never reached target — fine
                    Some(t) => assert!(
                        ns_tta.unwrap() <= t,
                        "{}: {} reached target faster",
                        panel.bw_label,
                        other.method
                    ),
                }
            }
        }
    }
}
