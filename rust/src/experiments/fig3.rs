//! Fig. 3 — Adaptive quantization based on L2 norm: the Algorithm-2
//! decision surface. For a grid of (ratio, gradient-energy) operating
//! points, show whether quantization fires, the effective ratio, the
//! pruning rate, and the resulting wire size — the figure's flowchart as a
//! table.

use super::report::Table;
use super::scenario::RunOpts;
use crate::compress::{CompressionConfig, NetSenseCompressor};
use crate::util::rng::Pcg64;

pub struct Fig3Row {
    pub ratio: f64,
    pub grad_scale: f32,
    pub quantized: bool,
    pub effective_ratio: f64,
    pub pruning_rate: f64,
    pub wire_bytes: u64,
}

pub fn fig3(_opts: &RunOpts) -> (Table, Vec<Fig3Row>) {
    let n = 100_000usize;
    let mut rng = Pcg64::seeded(3);
    let mut base = vec![0f32; n];
    rng.fill_normal_f32(&mut base, 0.0, 1.0);
    let mut weights = vec![0f32; n];
    rng.fill_normal_f32(&mut weights, 0.0, 0.1);

    let mut table = Table::new(
        "Fig 3: adaptive quantization decisions (tr_q = 0.05, tr_d = 1e-3)",
        &[
            "Ratio",
            "||g||2",
            "Quantized?",
            "Effective ratio",
            "Pruning rate",
            "Wire bytes",
            "Dense bytes",
        ],
    );
    let mut rows = Vec::new();
    for &ratio in &[0.2, 0.1, 0.05, 0.04, 0.02, 0.01, 0.005] {
        for &scale in &[1.0f32, 1e-6] {
            // fresh compressor: no residual carry-over between cells
            let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
            let g: Vec<f32> = base.iter().map(|&x| x * scale).collect();
            let out = c.compress(&g, &weights, ratio);
            table.row(vec![
                format!("{ratio}"),
                format!("{:.2e}", out.grad_l2),
                if out.quantized { "yes (f32→f16)" } else { "no" }.to_string(),
                format!("{:.3}", out.effective_ratio),
                format!("{:.3}", out.pruning_rate),
                out.wire_bytes.to_string(),
                out.dense_bytes.to_string(),
            ]);
            rows.push(Fig3Row {
                ratio,
                grad_scale: scale,
                quantized: out.quantized,
                effective_ratio: out.effective_ratio,
                pruning_rate: out.pruning_rate,
                wire_bytes: out.wire_bytes,
            });
        }
    }
    (table, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_surface_matches_algorithm2() {
        let (_, rows) = fig3(&RunOpts::default());
        for r in &rows {
            let should_quantize = r.ratio < 0.05 && r.grad_scale > 1e-5;
            assert_eq!(
                r.quantized, should_quantize,
                "ratio {} scale {}",
                r.ratio, r.grad_scale
            );
            if r.quantized {
                assert!((r.effective_ratio - (2.0 * r.ratio).min(1.0)).abs() < 1e-12);
            } else {
                assert!((r.effective_ratio - r.ratio).abs() < 1e-12);
            }
            // Pruning rate rule on the effective ratio.
            assert!((r.pruning_rate - 0.5 * (1.0 - r.effective_ratio)).abs() < 1e-9);
        }
        // Quantization halves the per-element wire cost: compare the two
        // 0.04-ratio rows (quantized) against 0.1-ratio (not).
        let q = rows.iter().find(|r| r.ratio == 0.04 && r.quantized).unwrap();
        // effective 0.08 → nnz = 8000, 6 B each + 12 header
        assert_eq!(q.wire_bytes, 12 + 8000 * 6);
    }
}
