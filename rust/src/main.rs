//! `netsenseml` — the L3 coordinator CLI.
//!
//! Subcommands:
//! - `repro <exp|all>` — regenerate the paper's tables/figures
//! - `train`           — one simulated training run (paper-scale models)
//! - `live`            — live multi-worker training over real sockets
//! - `e2e`             — real three-layer training (PJRT + JAX/Pallas)
//! - `sense`           — Fig.2-style sensing sweep
//! - `info`            — artifact/manifest inspection

use netsenseml::config::{LiveConfig, TrainConfig};
use netsenseml::util::error::{anyhow, bail, Result};
use netsenseml::coordinator::{
    run_sim_training, RealTrainConfig, RealTrainer, SimTrainConfig, SyncStrategy,
};
use netsenseml::experiments::scenario::{RunOpts, Scenario};
use netsenseml::experiments::{ablation, degrading, fig2, fig3, fluctuating, pipelined, tables, tta};
use netsenseml::netsim::schedule::mbps;
use netsenseml::netsim::topology::StarTopology;
use netsenseml::netsim::{NetSim, SimTime};
use netsenseml::runtime::{Manifest, ModelRuntime};
use netsenseml::trainer::models::PaperModel;
use netsenseml::util::cli::{flag, opt, Cli, CmdSpec};
use std::path::{Path, PathBuf};

fn cli() -> Cli {
    Cli {
        bin: "netsenseml",
        about: "Network-adaptive gradient compression for distributed ML (paper reproduction)",
        commands: vec![
            CmdSpec {
                name: "repro",
                help: "regenerate paper tables/figures (table1 table2 fig2 fig3 fig5 fig6 fig7 fig8 pipeline | all)",
                opts: vec![
                    opt("out", "directory for CSV outputs", None),
                    flag("fast", "10x shorter horizons (CI smoke)"),
                    opt("seed", "experiment seed", Some("42")),
                    opt("workers", "number of workers", Some("8")),
                    opt("fidelity-every", "full-compression cadence in steps (0=never)", Some("250")),
                    flag("quiet", "only warnings/errors on stderr"),
                    flag("verbose", "debug-level progress on stderr"),
                ],
                positionals: vec!["experiment"],
            },
            CmdSpec {
                name: "train",
                help: "one simulated training run on a paper-scale model",
                opts: vec![
                    opt("config", "TOML config file (overrides defaults)", None),
                    opt("model", "resnet18 | vgg16", Some("resnet18")),
                    opt("strategy", "netsense | allreduce | topk[:r]", Some("netsense")),
                    opt("bw-mbps", "bottleneck bandwidth (Mbps)", Some("200")),
                    opt("vtime", "virtual-time horizon (s)", Some("600")),
                    opt("workers", "number of workers", Some("8")),
                    opt("seed", "seed", Some("42")),
                    opt("bucket-kb", "pipelined-exchange bucket (KiB dense; 0 = monolithic)", None),
                    opt("pipeline-depth", "pipelined-exchange lookahead stages", None),
                    opt("csv", "write the step trace to this CSV", None),
                ],
                positionals: vec![],
            },
            CmdSpec {
                name: "live",
                help: "live multi-worker training over real sockets (loopback | tcp)",
                opts: vec![
                    opt("config", "TOML config with [transport]/[live] tables", None),
                    opt("workers", "number of workers (threads, one socket endpoint each)", None),
                    opt("steps", "training steps", None),
                    opt("params", "flat gradient length (f32 elements)", None),
                    opt("strategy", "netsense | allreduce | topk[:r]", None),
                    opt("backend", "loopback | tcp", None),
                    opt("bind", "tcp rendezvous address (host:port; port 0 = auto)", None),
                    opt("poller-threads", "event-loop threads for the socket poller (0 = auto)", None),
                    opt("rate-mbps", "token-bucket shaping rate (0 = unshaped)", None),
                    opt("burst-kb", "token-bucket burst", None),
                    opt("prop-delay-ms", "per-send propagation-delay floor", None),
                    opt("step-down", "halve-style rate step: `<at_s>:<mbps>`", None),
                    opt("compute-ms", "local compute time per step", None),
                    opt("seed", "seed", None),
                    opt("kill", "chaos: kill a rank mid-run: `<rank>:<step>`", None),
                    opt("stall", "chaos: stall a rank: `<rank>:<step>:<ms>`", None),
                    opt("flap", "chaos: flap a rank's link: `<rank>:<step>:<down_ms>`", None),
                    opt("duplicate", "chaos: replay a rank's frames one step late: `<rank>:<step>`", None),
                    opt("reorder", "chaos: withhold a rank's data past its round: `<rank>:<step>`", None),
                    opt("partial-kill", "chaos: torn write then death: `<rank>:<step>:<keep_bytes>`", None),
                    opt("recv-timeout-ms", "failure detector: per-recv deadline", None),
                    opt("probe-timeout-ms", "failure detector: recovery probe deadline", None),
                    opt("trace-out", "write per-rank spans as Chrome trace JSON (Perfetto)", None),
                    opt("journal-out", "write rank 0's controller decision journal (JSON)", None),
                    opt("metrics-out", "write a Prometheus-text metrics snapshot", None),
                    flag("obs-collect", "gather every rank's telemetry to rank 0 (clock-aligned merge)"),
                    opt("analysis-out", "write critical-path attribution (ANALYSIS.json; implies --obs-collect)", None),
                    opt("metrics-addr", "serve /metrics over HTTP while the run lasts (host:port)", None),
                    flag("quiet", "only warnings/errors on stderr"),
                    flag("verbose", "debug-level progress on stderr"),
                ],
                positionals: vec![],
            },
            CmdSpec {
                name: "e2e",
                help: "real training through PJRT (requires `make artifacts`)",
                opts: vec![
                    opt("model", "mlp | cifar_cnn", Some("mlp")),
                    opt("strategy", "netsense | allreduce | topk[:r]", Some("netsense")),
                    opt("steps", "training steps", Some("100")),
                    opt("workers", "simulated DDP workers", Some("4")),
                    opt("bw-mbps", "bottleneck bandwidth (Mbps)", Some("200")),
                    opt("lr", "learning rate", Some("0.02")),
                    opt("artifacts", "artifact directory", Some("artifacts")),
                    opt("csv", "write the step trace to this CSV", None),
                ],
                positionals: vec![],
            },
            CmdSpec {
                name: "sense",
                help: "network sensing sweep (Fig 2)",
                opts: vec![opt("out", "CSV output directory", None)],
                positionals: vec![],
            },
            CmdSpec {
                name: "info",
                help: "inspect the AOT artifact manifest",
                opts: vec![opt("artifacts", "artifact directory", Some("artifacts"))],
                positionals: vec![],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = cli();
    let args = match app.parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    // Progress/diagnostics ride the leveled stderr logger; --quiet and
    // --verbose move the bar (flags default to false on commands that
    // don't declare them).
    if args.flag("quiet") {
        netsenseml::util::log::set_level(netsenseml::util::log::Level::Warn);
    } else if args.flag("verbose") {
        netsenseml::util::log::set_level(netsenseml::util::log::Level::Debug);
    }
    let result = match args.command.as_str() {
        "repro" => cmd_repro(&args),
        "train" => cmd_train(&args),
        "live" => cmd_live(&args),
        "e2e" => cmd_e2e(&args),
        "sense" => cmd_sense(&args),
        "info" => cmd_info(&args),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run_opts_from(args: &netsenseml::util::cli::Args) -> Result<RunOpts> {
    Ok(RunOpts {
        fast: args.flag("fast"),
        out_dir: args.get("out").map(PathBuf::from),
        seed: args.get_u64("seed")?.unwrap_or(42),
        n_workers: args.get_usize("workers")?.unwrap_or(8),
        fidelity_every: args.get_usize("fidelity-every")?.unwrap_or(250),
    })
}

fn cmd_repro(args: &netsenseml::util::cli::Args) -> Result<()> {
    let opts = run_opts_from(args)?;
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let known = [
        "table1", "table2", "fig2", "fig3", "fig5", "fig6", "fig7", "fig8", "ablation",
        "pipeline",
    ];
    let selected: Vec<&str> = if which == "all" {
        known.to_vec()
    } else if known.contains(&which) {
        vec![which]
    } else {
        bail!("unknown experiment `{which}` (have {known:?} or `all`)");
    };
    for exp in selected {
        netsenseml::log_info!("== running {exp} ==");
        let t0 = std::time::Instant::now();
        match exp {
            "table1" => tables::table1(&opts).0.print(),
            "table2" => tables::table2(&opts).0.print(),
            "fig2" => {
                let (t, r) = fig2::fig2(&opts);
                t.print();
                println!(
                    "estimator: BtlBw {:.1} Mbps (true {:.1}), RTprop {:.1} ms (true {:.1}), BDP {:.0} kB",
                    r.est_btlbw_mbps,
                    r.true_btlbw_mbps,
                    r.est_rtprop_ms,
                    r.true_rtprop_ms,
                    r.est_bdp_bytes / 1e3
                );
            }
            "fig3" => fig3::fig3(&opts).0.print(),
            "ablation" => ablation::ablation(&opts).0.print(),
            "fig5" => tta::fig5(&opts).0.print(),
            "fig6" => tta::fig6(&opts).0.print(),
            "fig7" => degrading::fig7(&opts).0.print(),
            "fig8" => fluctuating::fig8(&opts).0.print(),
            "pipeline" => pipelined::pipeline_overlap(&opts).0.print(),
            _ => unreachable!(),
        }
        netsenseml::log_info!("{exp} took {:.1}s", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_train(args: &netsenseml::util::cli::Args) -> Result<()> {
    // Layer: defaults ← TOML ← CLI flags.
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::from_toml_file(Path::new(path))?,
        None => TrainConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(s) = args.get("strategy") {
        cfg.strategy = s.to_string();
    }
    if let Some(b) = args.get_f64("bw-mbps")? {
        cfg.bandwidth_mbps = b;
    }
    if let Some(v) = args.get_f64("vtime")? {
        cfg.max_vtime_s = v;
    }
    if let Some(w) = args.get_usize("workers")? {
        cfg.n_workers = w;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    if let Some(b) = args.get_u64("bucket-kb")? {
        cfg.bucket_kb = b;
    }
    if let Some(d) = args.get_usize("pipeline-depth")? {
        cfg.pipeline_depth = d;
    }
    cfg.validate()?;

    let model = PaperModel::by_name(&cfg.model)
        .ok_or_else(|| anyhow!("unknown paper model `{}` (resnet18|vgg16)", cfg.model))?;
    let strategy = SyncStrategy::parse(&cfg.strategy).unwrap();
    let mut sim_cfg = SimTrainConfig::new(model, strategy);
    sim_cfg.n_workers = cfg.n_workers;
    sim_cfg.batch_per_worker = cfg.batch_per_worker;
    sim_cfg.max_vtime_s = cfg.max_vtime_s;
    sim_cfg.fidelity_every = cfg.fidelity_every;
    sim_cfg.seed = cfg.seed;
    sim_cfg.pipeline = cfg.pipeline();
    let mut sim = Scenario::static_bottleneck(cfg.n_workers, mbps(cfg.bandwidth_mbps));
    let log = run_sim_training(&sim_cfg, &mut sim)?;

    println!(
        "model={} strategy={} bw={} Mbps workers={}",
        cfg.model, cfg.strategy, cfg.bandwidth_mbps, cfg.n_workers
    );
    println!(
        "steps={} vtime={:.1}s throughput={:.1} samples/s best_acc={:.2}% convergence={}",
        log.records.len(),
        log.total_vtime(),
        log.mean_throughput(),
        log.best_acc(),
        netsenseml::experiments::report::opt_time(log.convergence_time()),
    );
    if let Some(csv) = args.get("csv") {
        log.write_csv(Path::new(csv))?;
        println!("trace written to {csv}");
    }
    Ok(())
}

fn cmd_live(args: &netsenseml::util::cli::Args) -> Result<()> {
    // Layer: defaults ← TOML ← CLI flags.
    let mut cfg = match args.get("config") {
        Some(path) => LiveConfig::from_toml_file(Path::new(path))?,
        None => LiveConfig::default(),
    };
    if let Some(w) = args.get_usize("workers")? {
        cfg.transport.n_workers = w;
    }
    if let Some(s) = args.get_usize("steps")? {
        cfg.steps = s;
    }
    if let Some(p) = args.get_usize("params")? {
        cfg.n_params = p;
    }
    if let Some(s) = args.get("strategy") {
        cfg.strategy = s.to_string();
    }
    if let Some(b) = args.get("backend") {
        cfg.transport.backend = b.to_string();
    }
    if let Some(b) = args.get("bind") {
        cfg.transport.bind = b.to_string();
    }
    if let Some(p) = args.get_usize("poller-threads")? {
        cfg.transport.poller_threads = p;
    }
    if let Some(r) = args.get_f64("rate-mbps")? {
        cfg.transport.rate_mbps = r;
        // A schedule entry at t = 0 restates the base rate and would
        // silently override this flag from the first instant — drop it;
        // later steps still apply.
        cfg.transport.schedule.retain(|&(at, _)| at > 0.0);
    }
    if let Some(b) = args.get_f64("burst-kb")? {
        cfg.transport.burst_kb = b;
    }
    if let Some(d) = args.get_f64("prop-delay-ms")? {
        cfg.transport.prop_delay_ms = d;
    }
    if let Some(spec) = args.get("step-down") {
        if cfg.transport.rate_mbps <= 0.0 {
            bail!("--step-down needs a base rate: pass --rate-mbps > 0");
        }
        let (at, mbps) = spec
            .split_once(':')
            .and_then(|(a, r)| Some((a.parse::<f64>().ok()?, r.parse::<f64>().ok()?)))
            .ok_or_else(|| anyhow!("--step-down wants `<at_s>:<mbps>`, got `{spec}`"))?;
        cfg.transport.schedule = vec![(0.0, cfg.transport.rate_mbps), (at, mbps)];
    }
    if let Some(c) = args.get_u64("compute-ms")? {
        cfg.compute_ms = c;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    if let Some(spec) = args.get("kill") {
        let (rank, step) = parse_colon_pair(spec)
            .ok_or_else(|| anyhow!("--kill wants `<rank>:<step>`, got `{spec}`"))?;
        cfg.faults.kills.push((rank, step));
    }
    if let Some(spec) = args.get("stall") {
        let (rank, step, ms) = parse_colon_triple(spec)
            .ok_or_else(|| anyhow!("--stall wants `<rank>:<step>:<ms>`, got `{spec}`"))?;
        cfg.faults.stalls.push((rank, step, ms));
    }
    if let Some(spec) = args.get("flap") {
        let (rank, step, ms) = parse_colon_triple(spec)
            .ok_or_else(|| anyhow!("--flap wants `<rank>:<step>:<down_ms>`, got `{spec}`"))?;
        cfg.faults.flaps.push((rank, step, ms));
    }
    if let Some(spec) = args.get("duplicate") {
        let (rank, step) = parse_colon_pair(spec)
            .ok_or_else(|| anyhow!("--duplicate wants `<rank>:<step>`, got `{spec}`"))?;
        cfg.faults.duplicates.push((rank, step));
    }
    if let Some(spec) = args.get("reorder") {
        let (rank, step) = parse_colon_pair(spec)
            .ok_or_else(|| anyhow!("--reorder wants `<rank>:<step>`, got `{spec}`"))?;
        cfg.faults.reorders.push((rank, step));
    }
    if let Some(spec) = args.get("partial-kill") {
        let (rank, step, keep) = parse_colon_triple(spec)
            .ok_or_else(|| anyhow!("--partial-kill wants `<rank>:<step>:<keep_bytes>`, got `{spec}`"))?;
        cfg.faults.partial_kills.push((rank, step, keep as usize));
    }
    if let Some(v) = args.get_u64("recv-timeout-ms")? {
        cfg.fault.recv_timeout_ms = v;
    }
    if let Some(v) = args.get_u64("probe-timeout-ms")? {
        cfg.fault.probe_timeout_ms = v;
    }
    // Asking for an artifact implies capturing it.
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let journal_out = args.get("journal-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let analysis_out = args.get("analysis-out").map(PathBuf::from);
    if trace_out.is_some() {
        cfg.obs.trace = true;
    }
    if journal_out.is_some() {
        cfg.obs.journal = true;
    }
    if args.flag("obs-collect") || analysis_out.is_some() {
        // The gather ships span rings and journals; the analyzer needs
        // both — collecting empty rings would be ceremony.
        cfg.obs.collect = true;
        cfg.obs.trace = true;
        cfg.obs.journal = true;
    }
    cfg.validate()?;

    // A tiny scrape endpoint for the duration of the run (shut down on
    // drop, rendered requests read the same global registry the snapshot
    // file does).
    let _metrics_server = match args.get("metrics-addr") {
        Some(addr) => {
            let server = netsenseml::obs::MetricsServer::start(addr)?;
            netsenseml::log_info!("serving metrics at http://{}/metrics", server.local_addr());
            Some(server)
        }
        None => None,
    };

    let opts = cfg.live_opts();
    netsenseml::log_info!(
        "live: {} workers over {} — strategy {}, {} steps × {} params{}{}",
        opts.n_workers,
        cfg.transport.backend,
        cfg.strategy,
        opts.steps,
        opts.n_params,
        match &opts.shaping {
            Some(s) => format!(
                ", shaped to {:.1} Mbps ({} steps)",
                s.rate_bytes_per_sec * 8.0 / 1e6,
                s.schedule.len()
            ),
            None => ", unshaped".to_string(),
        },
        if opts.faults.is_empty() {
            String::new()
        } else {
            format!(
                ", chaos: {} kill(s) {} stall(s) {} flap(s) {} dup(s) {} reorder(s) {} partial(s)",
                opts.faults.kills.len(),
                opts.faults.stalls.len(),
                opts.faults.flaps.len(),
                opts.faults.duplicates.len(),
                opts.faults.reorders.len(),
                opts.faults.partial_kills.len()
            )
        }
    );
    let report = netsenseml::experiments::live::run_live(&opts)?;

    let mut table = netsenseml::experiments::Table::new(
        "Live training — measured observables (rank 0)",
        &["Step", "t (s)", "Epoch", "Live", "Ratio", "Payload (kB)", "Round (ms)", "Sensed BtlBw (Mbps)"],
    );
    let stride = (report.steps.len() / 12).max(1);
    for r in report.steps.iter().step_by(stride) {
        table.row(vec![
            r.step.to_string(),
            format!("{:.2}", r.at_s),
            r.epoch.to_string(),
            r.live.to_string(),
            format!("{:.4}", r.ratio),
            format!("{:.1}", r.payload_bytes as f64 / 1e3),
            format!("{:.1}", r.round_ms),
            r.btlbw_mbps
                .map(|b| format!("{b:.1}"))
                .unwrap_or_else(|| "—".to_string()),
        ]);
    }
    table.print();
    println!(
        "steps={} wall={:.1}s final_ratio={:.4} ctl(+{} / −{}) recoveries={} lost={} live={}/{} replicas {}",
        report.steps.len(),
        report.wall_s,
        report.final_ratio,
        report.controller_increases,
        report.controller_decreases,
        report.recoveries,
        report.lost_intervals,
        report.final_live,
        opts.n_workers,
        if report.consistent {
            "bit-identical"
        } else {
            "DIVERGED"
        }
    );
    // Telemetry artifacts are written even for a diverged run — they're
    // exactly what a post-mortem needs.
    if let Some(path) = &trace_out {
        std::fs::write(path, report.trace_json())?;
        netsenseml::log_info!(
            "trace written to {} ({} spans, {} dropped)",
            path.display(),
            report.spans.len(),
            report.spans_dropped
        );
    }
    if let Some(path) = &journal_out {
        std::fs::write(path, report.journal_json())?;
        netsenseml::log_info!(
            "journal written to {} ({} records, {} dropped)",
            path.display(),
            report.journal.len(),
            report.journal_dropped
        );
    }
    if let Some(path) = &metrics_out {
        std::fs::write(path, netsenseml::obs::registry().prometheus())?;
        netsenseml::log_info!("metrics snapshot written to {}", path.display());
    }
    if let Some(path) = &analysis_out {
        match report.analysis_json() {
            Some(json) => {
                std::fs::write(path, json)?;
                netsenseml::log_info!("analysis written to {}", path.display());
            }
            None => netsenseml::log_warn!(
                "no analysis to write to {} (collection gathered no spans)",
                path.display()
            ),
        }
    }
    for note in &report.collect_notes {
        netsenseml::log_warn!("telemetry gather: {note}");
    }
    if let Some(a) = &report.analysis {
        match a.straggler_verdict {
            Some(r) => netsenseml::log_info!(
                "critical path: rank {r} dominated ({}/{} attributed rounds)",
                a.straggler_counts.get(r).copied().unwrap_or(0),
                a.straggler_counts.iter().sum::<u64>()
            ),
            None => netsenseml::log_info!("critical path: no dominant straggler"),
        }
        if a.congestion_verdict {
            netsenseml::log_info!("congestion: lossy intervals drove controller backoffs");
        }
    }
    // Worker errors surface only after every artifact is on disk — the
    // flight-recorder telemetry is exactly what the post-mortem needs.
    if !report.worker_errors.is_empty() {
        bail!("worker(s) aborted: {}", report.worker_errors.join("; "));
    }
    if !report.consistent {
        bail!("reduced gradients diverged across surviving workers");
    }
    Ok(())
}

/// `a:b` → (a, b).
fn parse_colon_pair(spec: &str) -> Option<(usize, usize)> {
    let (a, b) = spec.split_once(':')?;
    Some((a.trim().parse().ok()?, b.trim().parse().ok()?))
}

/// `a:b:c` → (a, b, c).
fn parse_colon_triple(spec: &str) -> Option<(usize, usize, u64)> {
    let (a, rest) = spec.split_once(':')?;
    let (b, c) = rest.split_once(':')?;
    Some((
        a.trim().parse().ok()?,
        b.trim().parse().ok()?,
        c.trim().parse().ok()?,
    ))
}

fn cmd_e2e(args: &netsenseml::util::cli::Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let model = args.get_or("model", "mlp");
    let strategy = SyncStrategy::parse(&args.get_or("strategy", "netsense"))
        .ok_or_else(|| anyhow!("bad strategy"))?;
    let steps = args.get_usize("steps")?.unwrap_or(100);
    let workers = args.get_usize("workers")?.unwrap_or(4);
    let bw = args.get_f64("bw-mbps")?.unwrap_or(200.0);
    let lr = args.get_f64("lr")?.unwrap_or(0.02) as f32;

    let rt = ModelRuntime::load(&artifacts, &model)?;
    println!(
        "loaded {} on {} ({} params)",
        model,
        rt.platform(),
        rt.manifest.total_params
    );
    let config = RealTrainConfig {
        n_workers: workers,
        strategy,
        steps,
        lr,
        eval_every: 10,
        seed: 7,
    };
    let mut trainer = RealTrainer::new(&rt, config)?;
    let mut sim = NetSim::quiet(StarTopology::constant(
        workers,
        mbps(bw),
        SimTime::from_millis(10),
    ));
    let t0 = std::time::Instant::now();
    let log = trainer.train(&mut sim)?;
    let wall = t0.elapsed().as_secs_f64();
    let first = log.records.first().unwrap();
    let last = log.records.last().unwrap();
    println!(
        "steps={} wall={:.1}s vtime={:.1}s loss {:.3}→{:.3} acc={:.1}% ratio(last)={:.4}",
        log.records.len(),
        wall,
        log.total_vtime(),
        first.loss,
        last.loss,
        last.acc,
        last.ratio
    );
    if let Some(csv) = args.get("csv") {
        log.write_csv(Path::new(csv))?;
        println!("trace written to {csv}");
    }
    Ok(())
}

fn cmd_sense(args: &netsenseml::util::cli::Args) -> Result<()> {
    let opts = RunOpts {
        out_dir: args.get("out").map(PathBuf::from),
        ..Default::default()
    };
    let (t, r) = fig2::fig2(&opts);
    t.print();
    println!(
        "estimator: BtlBw {:.1} Mbps (true {:.1}) RTprop {:.1} ms (true {:.1}) BDP {:.0} kB",
        r.est_btlbw_mbps, r.true_btlbw_mbps, r.est_rtprop_ms, r.true_rtprop_ms,
        r.est_bdp_bytes / 1e3
    );
    Ok(())
}

fn cmd_info(args: &netsenseml::util::cli::Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let manifest = Manifest::load(&dir)?;
    for m in &manifest.models {
        println!(
            "model {} — batch {}, input {:?}, {} classes, {} params in {} tensors",
            m.name,
            m.batch,
            m.input_shape,
            m.n_classes,
            m.total_params,
            m.params.len()
        );
        println!("  grad_step:    {}", m.grad_step_file.display());
        println!("  apply_update: {}", m.apply_update_file.display());
    }
    Ok(())
}
