//! Typed experiment configuration, loadable from TOML files (see
//! `configs/*.toml`) with CLI overrides layered on top.

use crate::coordinator::PipelineConfig;
use crate::experiments::scenario::RunOpts;
use crate::util::error::{anyhow, Result};
use crate::util::toml::TomlDoc;
use std::path::Path;

/// Everything a `netsenseml train` run needs.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: String,
    pub strategy: String,
    pub n_workers: usize,
    pub batch_per_worker: usize,
    pub bandwidth_mbps: f64,
    pub prop_delay_ms: u64,
    pub max_vtime_s: f64,
    pub fidelity_every: usize,
    pub seed: u64,
    /// Compression-bucket size for the pipelined exchange, in KiB of dense
    /// gradient (0 = monolithic compress-then-send, the pre-pipeline path).
    pub bucket_kb: u64,
    /// Lookahead stages of the pipelined exchange.
    pub pipeline_depth: usize,
    /// BDP-adaptive transport staging (shrink in-flight units under
    /// congestion).
    pub pipeline_adaptive: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "resnet18".to_string(),
            strategy: "netsense".to_string(),
            n_workers: 8,
            batch_per_worker: 32,
            bandwidth_mbps: 200.0,
            prop_delay_ms: 10,
            max_vtime_s: 600.0,
            fidelity_every: 250,
            seed: 42,
            bucket_kb: 0,
            pipeline_depth: 2,
            pipeline_adaptive: true,
        }
    }
}

impl TrainConfig {
    /// Load from a TOML file; missing keys keep their defaults.
    pub fn from_toml_file(path: &Path) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<TrainConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut c = TrainConfig::default();
        if let Some(v) = doc.get_str("train.model") {
            c.model = v.to_string();
        }
        if let Some(v) = doc.get_str("train.strategy") {
            c.strategy = v.to_string();
        }
        if let Some(v) = doc.get_i64("train.n_workers") {
            c.n_workers = v as usize;
        }
        if let Some(v) = doc.get_i64("train.batch_per_worker") {
            c.batch_per_worker = v as usize;
        }
        if let Some(v) = doc.get_f64("net.bandwidth_mbps") {
            c.bandwidth_mbps = v;
        }
        if let Some(v) = doc.get_i64("net.prop_delay_ms") {
            c.prop_delay_ms = v as u64;
        }
        if let Some(v) = doc.get_f64("train.max_vtime_s") {
            c.max_vtime_s = v;
        }
        if let Some(v) = doc.get_i64("train.fidelity_every") {
            c.fidelity_every = v as usize;
        }
        if let Some(v) = doc.get_i64("train.seed") {
            c.seed = v as u64;
        }
        if let Some(v) = doc.get_i64("pipeline.bucket_kb") {
            if v < 0 {
                return Err(anyhow!("pipeline.bucket_kb must be ≥ 0 (got {v})"));
            }
            c.bucket_kb = v as u64;
        }
        if let Some(v) = doc.get_i64("pipeline.depth") {
            if v < 0 {
                return Err(anyhow!("pipeline.depth must be ≥ 0 (got {v})"));
            }
            c.pipeline_depth = v as usize;
        }
        if let Some(v) = doc.get_bool("pipeline.adaptive") {
            c.pipeline_adaptive = v;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_workers == 0 {
            return Err(anyhow!("n_workers must be ≥ 1"));
        }
        if self.bandwidth_mbps <= 0.0 {
            return Err(anyhow!("bandwidth_mbps must be positive"));
        }
        if crate::coordinator::SyncStrategy::parse(&self.strategy).is_none() {
            return Err(anyhow!(
                "unknown strategy `{}` (netsense|allreduce|topk[:r])",
                self.strategy
            ));
        }
        Ok(())
    }

    /// The pipelined-exchange config this run asks for (None = monolithic).
    pub fn pipeline(&self) -> Option<PipelineConfig> {
        if self.bucket_kb == 0 {
            return None;
        }
        Some(PipelineConfig {
            bucket_size_bytes: self.bucket_kb.saturating_mul(1024),
            pipeline_depth: self.pipeline_depth,
            adaptive: self.pipeline_adaptive,
            ..Default::default()
        })
    }

    pub fn run_opts(&self) -> RunOpts {
        RunOpts {
            fast: false,
            out_dir: None,
            seed: self.seed,
            n_workers: self.n_workers,
            fidelity_every: self.fidelity_every,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let c = TrainConfig::from_toml(
            r#"
[train]
model = "vgg16"
strategy = "topk:0.05"
n_workers = 4
max_vtime_s = 120.5
[net]
bandwidth_mbps = 500
prop_delay_ms = 25
"#,
        )
        .unwrap();
        assert_eq!(c.model, "vgg16");
        assert_eq!(c.strategy, "topk:0.05");
        assert_eq!(c.n_workers, 4);
        assert_eq!(c.bandwidth_mbps, 500.0);
        assert_eq!(c.prop_delay_ms, 25);
        assert_eq!(c.max_vtime_s, 120.5);
        // untouched default
        assert_eq!(c.batch_per_worker, 32);
    }

    #[test]
    fn pipeline_section_parses() {
        // Default: pipeline off.
        assert_eq!(TrainConfig::default().pipeline(), None);
        let c = TrainConfig::from_toml(
            r#"
[pipeline]
bucket_kb = 2048
depth = 4
adaptive = false
"#,
        )
        .unwrap();
        assert_eq!(c.bucket_kb, 2048);
        let p = c.pipeline().unwrap();
        assert_eq!(p.bucket_size_bytes, 2048 * 1024);
        assert_eq!(p.pipeline_depth, 4);
        assert!(!p.adaptive);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(TrainConfig::from_toml("[train]\nstrategy = \"bogus\"").is_err());
        assert!(TrainConfig::from_toml("[train]\nn_workers = 0").is_err());
        assert!(TrainConfig::from_toml("[net]\nbandwidth_mbps = -5").is_err());
        assert!(TrainConfig::from_toml("[pipeline]\nbucket_kb = -1").is_err());
        assert!(TrainConfig::from_toml("[pipeline]\ndepth = -2").is_err());
        assert!(TrainConfig::from_toml("not toml at all").is_err());
    }
}
