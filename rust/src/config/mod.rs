//! Typed experiment configuration, loadable from TOML files (see
//! `configs/*.toml`) with CLI overrides layered on top.
//!
//! Two shapes: [`TrainConfig`] for simulated runs (`[train]` / `[net]` /
//! `[pipeline]`) and [`LiveConfig`] for live-socket runs (`[transport]` /
//! `[live]` / `[fault]` / `[obs]`, see `configs/live.toml`). The live
//! tables reject unknown keys — a typo in a transport knob must fail
//! loudly, not silently fall back to a default backend.

use crate::coordinator::PipelineConfig;
use crate::experiments::live::{LiveBackend, LiveOpts, ObsOpts};
use crate::experiments::scenario::RunOpts;
use crate::fault::{FaultConfig, FaultSchedule};
use crate::transport::ShapingConfig;
use crate::util::error::{anyhow, Result};
use crate::util::toml::{TomlDoc, TomlValue};
use std::path::Path;

/// Everything a `netsenseml train` run needs.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: String,
    pub strategy: String,
    pub n_workers: usize,
    pub batch_per_worker: usize,
    pub bandwidth_mbps: f64,
    pub prop_delay_ms: u64,
    pub max_vtime_s: f64,
    pub fidelity_every: usize,
    pub seed: u64,
    /// Compression-bucket size for the pipelined exchange, in KiB of dense
    /// gradient (0 = monolithic compress-then-send, the pre-pipeline path).
    pub bucket_kb: u64,
    /// Lookahead stages of the pipelined exchange.
    pub pipeline_depth: usize,
    /// BDP-adaptive transport staging (shrink in-flight units under
    /// congestion).
    pub pipeline_adaptive: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "resnet18".to_string(),
            strategy: "netsense".to_string(),
            n_workers: 8,
            batch_per_worker: 32,
            bandwidth_mbps: 200.0,
            prop_delay_ms: 10,
            max_vtime_s: 600.0,
            fidelity_every: 250,
            seed: 42,
            bucket_kb: 0,
            pipeline_depth: 2,
            pipeline_adaptive: true,
        }
    }
}

impl TrainConfig {
    /// Load from a TOML file; missing keys keep their defaults.
    pub fn from_toml_file(path: &Path) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<TrainConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut c = TrainConfig::default();
        if let Some(v) = doc.get_str("train.model") {
            c.model = v.to_string();
        }
        if let Some(v) = doc.get_str("train.strategy") {
            c.strategy = v.to_string();
        }
        if let Some(v) = doc.get_i64("train.n_workers") {
            c.n_workers = v as usize;
        }
        if let Some(v) = doc.get_i64("train.batch_per_worker") {
            c.batch_per_worker = v as usize;
        }
        if let Some(v) = doc.get_f64("net.bandwidth_mbps") {
            c.bandwidth_mbps = v;
        }
        if let Some(v) = doc.get_i64("net.prop_delay_ms") {
            c.prop_delay_ms = v as u64;
        }
        if let Some(v) = doc.get_f64("train.max_vtime_s") {
            c.max_vtime_s = v;
        }
        if let Some(v) = doc.get_i64("train.fidelity_every") {
            c.fidelity_every = v as usize;
        }
        if let Some(v) = doc.get_i64("train.seed") {
            c.seed = v as u64;
        }
        if let Some(v) = doc.get_i64("pipeline.bucket_kb") {
            if v < 0 {
                return Err(anyhow!("pipeline.bucket_kb must be ≥ 0 (got {v})"));
            }
            c.bucket_kb = v as u64;
        }
        if let Some(v) = doc.get_i64("pipeline.depth") {
            if v < 0 {
                return Err(anyhow!("pipeline.depth must be ≥ 0 (got {v})"));
            }
            c.pipeline_depth = v as usize;
        }
        if let Some(v) = doc.get_bool("pipeline.adaptive") {
            c.pipeline_adaptive = v;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_workers == 0 {
            return Err(anyhow!("n_workers must be ≥ 1"));
        }
        if self.bandwidth_mbps <= 0.0 {
            return Err(anyhow!("bandwidth_mbps must be positive"));
        }
        if crate::coordinator::SyncStrategy::parse(&self.strategy).is_none() {
            return Err(anyhow!(
                "unknown strategy `{}` (netsense|allreduce|topk[:r])",
                self.strategy
            ));
        }
        Ok(())
    }

    /// The pipelined-exchange config this run asks for (None = monolithic).
    pub fn pipeline(&self) -> Option<PipelineConfig> {
        if self.bucket_kb == 0 {
            return None;
        }
        Some(PipelineConfig {
            bucket_size_bytes: self.bucket_kb.saturating_mul(1024),
            pipeline_depth: self.pipeline_depth,
            adaptive: self.pipeline_adaptive,
            ..Default::default()
        })
    }

    pub fn run_opts(&self) -> RunOpts {
        RunOpts {
            fast: false,
            out_dir: None,
            seed: self.seed,
            n_workers: self.n_workers,
            fidelity_every: self.fidelity_every,
        }
    }
}

/// The `[transport]` table: which backend a live run uses and how its
/// links are shaped.
#[derive(Clone, Debug, PartialEq)]
pub struct TransportConfig {
    /// `loopback` (in-process channels) or `tcp` (localhost mesh).
    pub backend: String,
    /// Rank-0 rendezvous address for the TCP backend (`host:port`; port 0
    /// lets the OS pick).
    pub bind: String,
    pub n_workers: usize,
    /// Token-bucket rate limit, Mbps (0 = unshaped).
    pub rate_mbps: f64,
    /// Token-bucket burst, KiB.
    pub burst_kb: f64,
    /// Per-send propagation-delay floor, ms.
    pub prop_delay_ms: f64,
    /// Shaping steps: `(seconds from start, rate in Mbps)`.
    pub schedule: Vec<(f64, f64)>,
    /// Event-loop threads for the shared socket poller
    /// ([`crate::util::poller`]); 0 = auto (one per core, capped).
    pub poller_threads: usize,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            backend: "loopback".to_string(),
            bind: "127.0.0.1:29500".to_string(),
            n_workers: 2,
            rate_mbps: 0.0,
            burst_kb: 64.0,
            prop_delay_ms: 0.0,
            schedule: Vec::new(),
            poller_threads: 0,
        }
    }
}

/// Keys accepted under `[transport]` — anything else is rejected.
const TRANSPORT_KEYS: &[&str] = &[
    "transport.backend",
    "transport.bind",
    "transport.n_workers",
    "transport.rate_mbps",
    "transport.burst_kb",
    "transport.prop_delay_ms",
    "transport.schedule",
    "transport.poller_threads",
];

/// Keys accepted under `[live]`.
const LIVE_KEYS: &[&str] = &[
    "live.steps",
    "live.n_params",
    "live.strategy",
    "live.compute_ms",
    "live.seed",
];

/// Keys accepted under `[obs]` (telemetry capture).
const OBS_KEYS: &[&str] = &["obs.trace", "obs.trace_capacity", "obs.journal", "obs.collect"];

/// Keys accepted under `[fault]` (failure detector + chaos schedule).
const FAULT_KEYS: &[&str] = &[
    "fault.recv_timeout_ms",
    "fault.probe_timeout_ms",
    "fault.kill",
    "fault.stall",
    "fault.flap",
    "fault.duplicate",
    "fault.reorder",
    "fault.partial_kill",
];

/// Non-negative integer lookup with loud failures: a wrong-typed value
/// errors instead of falling back to the default, and a negative value
/// errors instead of wrapping through `as usize`/`as u64`.
fn get_nonneg(doc: &TomlDoc, path: &str) -> Result<Option<i64>> {
    match doc.get(path) {
        None => Ok(None),
        Some(v) => {
            let v = v
                .as_i64()
                .ok_or_else(|| anyhow!("{path} must be an integer"))?;
            if v < 0 {
                return Err(anyhow!("{path} must be ≥ 0 (got {v})"));
            }
            Ok(Some(v))
        }
    }
}

/// String lookup that errors on a wrong-typed value.
fn get_str_strict<'a>(doc: &'a TomlDoc, path: &str) -> Result<Option<&'a str>> {
    match doc.get(path) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| anyhow!("{path} must be a string")),
    }
}

/// Boolean lookup that errors on a wrong-typed value.
fn get_bool_strict(doc: &TomlDoc, path: &str) -> Result<Option<bool>> {
    match doc.get(path) {
        None => Ok(None),
        Some(v) => v
            .as_bool()
            .map(Some)
            .ok_or_else(|| anyhow!("{path} must be a boolean")),
    }
}

/// Numeric lookup (int coerces to float) that errors on a wrong-typed
/// value.
fn get_f64_strict(doc: &TomlDoc, path: &str) -> Result<Option<f64>> {
    match doc.get(path) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow!("{path} must be a number")),
    }
}

fn reject_unknown_keys(doc: &TomlDoc, section: &str, known: &[&str]) -> Result<()> {
    for key in doc.section_keys(section) {
        if !known.contains(&key) {
            return Err(anyhow!(
                "unknown key `{key}` in [{section}] (known: {})",
                known
                    .iter()
                    .map(|k| k.rsplit('.').next().unwrap())
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
    }
    Ok(())
}

impl TransportConfig {
    pub fn from_toml_doc(doc: &TomlDoc) -> Result<TransportConfig> {
        reject_unknown_keys(doc, "transport", TRANSPORT_KEYS)?;
        let mut c = TransportConfig::default();
        if let Some(v) = get_str_strict(doc, "transport.backend")? {
            c.backend = v.to_string();
        }
        if let Some(v) = get_str_strict(doc, "transport.bind")? {
            c.bind = v.to_string();
        }
        if let Some(v) = get_nonneg(doc, "transport.n_workers")? {
            c.n_workers = v as usize;
        }
        if let Some(v) = get_f64_strict(doc, "transport.rate_mbps")? {
            c.rate_mbps = v;
        }
        if let Some(v) = get_f64_strict(doc, "transport.burst_kb")? {
            c.burst_kb = v;
        }
        if let Some(v) = get_f64_strict(doc, "transport.prop_delay_ms")? {
            c.prop_delay_ms = v;
        }
        if let Some(v) = doc.get("transport.schedule") {
            c.schedule = parse_schedule(v)?;
        }
        if let Some(v) = get_nonneg(doc, "transport.poller_threads")? {
            c.poller_threads = v as usize;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.backend != "loopback" && self.backend != "tcp" {
            return Err(anyhow!(
                "unknown transport backend `{}` (loopback|tcp)",
                self.backend
            ));
        }
        if self.n_workers == 0 {
            return Err(anyhow!("transport.n_workers must be ≥ 1"));
        }
        if self.rate_mbps < 0.0 || self.burst_kb < 0.0 || self.prop_delay_ms < 0.0 {
            return Err(anyhow!("transport rates/burst/delay must be ≥ 0"));
        }
        if !self.schedule.is_empty() && self.rate_mbps <= 0.0 {
            // A schedule with no base rate would be silently unshaped.
            return Err(anyhow!(
                "transport.schedule requires a positive rate_mbps base rate"
            ));
        }
        if let Some(s) = self.shaping() {
            s.validate().map_err(|e| anyhow!("transport shaping: {e}"))?;
        }
        Ok(())
    }

    /// The token-bucket config this table asks for (None = unshaped).
    pub fn shaping(&self) -> Option<ShapingConfig> {
        if self.rate_mbps <= 0.0 {
            return None;
        }
        Some(ShapingConfig {
            rate_bytes_per_sec: self.rate_mbps * 1e6 / 8.0,
            burst_bytes: self.burst_kb * 1024.0,
            prop_delay_s: self.prop_delay_ms / 1e3,
            schedule: self
                .schedule
                .iter()
                .map(|&(at, mbps)| (at, mbps * 1e6 / 8.0))
                .collect(),
        })
    }

    pub fn live_backend(&self) -> LiveBackend {
        match self.backend.as_str() {
            "tcp" => LiveBackend::Tcp {
                bind: self.bind.clone(),
            },
            _ => LiveBackend::Loopback,
        }
    }
}

/// `[[at_s, rate_mbps], …]` from a TOML array of two-element arrays.
fn parse_schedule(v: &TomlValue) -> Result<Vec<(f64, f64)>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("transport.schedule must be an array of [at_s, rate_mbps]"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let pair = item
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow!("schedule entries must be two-element arrays"))?;
        let at = pair[0]
            .as_f64()
            .ok_or_else(|| anyhow!("schedule offset must be a number"))?;
        let rate = pair[1]
            .as_f64()
            .ok_or_else(|| anyhow!("schedule rate must be a number"))?;
        out.push((at, rate));
    }
    Ok(out)
}

/// `[[rank, step], …]` (arity 2) or `[[rank, step, ms], …]` (arity 3)
/// from a TOML array of integer rows, all entries non-negative.
fn parse_fault_rows(v: &TomlValue, path: &str, arity: usize) -> Result<Vec<Vec<i64>>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("{path} must be an array of {arity}-element integer rows"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let row = item
            .as_arr()
            .filter(|r| r.len() == arity)
            .ok_or_else(|| anyhow!("{path} entries must be {arity}-element arrays"))?;
        let mut vals = Vec::with_capacity(arity);
        for cell in row {
            let n = cell
                .as_i64()
                .ok_or_else(|| anyhow!("{path} entries must be integers"))?;
            if n < 0 {
                return Err(anyhow!("{path} entries must be ≥ 0 (got {n})"));
            }
            vals.push(n);
        }
        out.push(vals);
    }
    Ok(out)
}

/// Everything a `netsenseml live` run needs
/// (`[transport]` + `[live]` + `[fault]`).
#[derive(Clone, Debug, PartialEq)]
pub struct LiveConfig {
    pub transport: TransportConfig,
    pub steps: usize,
    pub n_params: usize,
    pub strategy: String,
    pub compute_ms: u64,
    pub seed: u64,
    /// Failure-detector deadlines.
    pub fault: FaultConfig,
    /// Chaos schedule (kills / stalls / link flaps, by rank and step).
    pub faults: FaultSchedule,
    /// Telemetry capture (`[obs]`).
    pub obs: ObsConfig,
}

/// The `[obs]` table: what telemetry a live run captures beyond the
/// always-on metrics registry.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Record per-rank tracing spans (Chrome `trace_event` export).
    pub trace: bool,
    /// Span-ring capacity per rank.
    pub trace_capacity: usize,
    /// Record each rank's controller decision journal.
    pub journal: bool,
    /// End-of-run cluster gather: ship every rank's telemetry to rank 0,
    /// clock-align the merged trace, run the critical-path analyzer.
    pub collect: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        let d = ObsOpts::default();
        ObsConfig {
            trace: d.trace,
            trace_capacity: d.trace_capacity,
            journal: d.journal,
            collect: d.collect,
        }
    }
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            transport: TransportConfig::default(),
            steps: 30,
            n_params: 100_000,
            strategy: "netsense".to_string(),
            compute_ms: 0,
            seed: 42,
            fault: FaultConfig::default(),
            faults: FaultSchedule::default(),
            obs: ObsConfig::default(),
        }
    }
}

impl LiveConfig {
    pub fn from_toml_file(path: &Path) -> Result<LiveConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<LiveConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("{e}"))?;
        // A misspelled *section* must fail as loudly as a misspelled key —
        // live configs know exactly three tables.
        for key in doc.entries.keys() {
            let section = key.split('.').next().unwrap_or(key);
            if section != "transport" && section != "live" && section != "fault" && section != "obs"
            {
                return Err(anyhow!(
                    "unknown section or key `{key}` (live configs use [transport], [live], \
                     [fault] and [obs])"
                ));
            }
        }
        reject_unknown_keys(&doc, "live", LIVE_KEYS)?;
        reject_unknown_keys(&doc, "fault", FAULT_KEYS)?;
        reject_unknown_keys(&doc, "obs", OBS_KEYS)?;
        let mut c = LiveConfig {
            transport: TransportConfig::from_toml_doc(&doc)?,
            ..Default::default()
        };
        if let Some(v) = get_nonneg(&doc, "live.steps")? {
            c.steps = v as usize;
        }
        if let Some(v) = get_nonneg(&doc, "live.n_params")? {
            c.n_params = v as usize;
        }
        if let Some(v) = get_str_strict(&doc, "live.strategy")? {
            c.strategy = v.to_string();
        }
        if let Some(v) = get_nonneg(&doc, "live.compute_ms")? {
            c.compute_ms = v as u64;
        }
        if let Some(v) = get_nonneg(&doc, "live.seed")? {
            c.seed = v as u64;
        }
        if let Some(v) = get_nonneg(&doc, "fault.recv_timeout_ms")? {
            c.fault.recv_timeout_ms = v as u64;
        }
        if let Some(v) = get_nonneg(&doc, "fault.probe_timeout_ms")? {
            c.fault.probe_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get("fault.kill") {
            c.faults.kills = parse_fault_rows(v, "fault.kill", 2)?
                .into_iter()
                .map(|r| (r[0] as usize, r[1] as usize))
                .collect();
        }
        if let Some(v) = doc.get("fault.stall") {
            c.faults.stalls = parse_fault_rows(v, "fault.stall", 3)?
                .into_iter()
                .map(|r| (r[0] as usize, r[1] as usize, r[2] as u64))
                .collect();
        }
        if let Some(v) = doc.get("fault.flap") {
            c.faults.flaps = parse_fault_rows(v, "fault.flap", 3)?
                .into_iter()
                .map(|r| (r[0] as usize, r[1] as usize, r[2] as u64))
                .collect();
        }
        if let Some(v) = doc.get("fault.duplicate") {
            c.faults.duplicates = parse_fault_rows(v, "fault.duplicate", 2)?
                .into_iter()
                .map(|r| (r[0] as usize, r[1] as usize))
                .collect();
        }
        if let Some(v) = doc.get("fault.reorder") {
            c.faults.reorders = parse_fault_rows(v, "fault.reorder", 2)?
                .into_iter()
                .map(|r| (r[0] as usize, r[1] as usize))
                .collect();
        }
        if let Some(v) = doc.get("fault.partial_kill") {
            c.faults.partial_kills = parse_fault_rows(v, "fault.partial_kill", 3)?
                .into_iter()
                .map(|r| (r[0] as usize, r[1] as usize, r[2] as usize))
                .collect();
        }
        if let Some(v) = get_bool_strict(&doc, "obs.trace")? {
            c.obs.trace = v;
        }
        if let Some(v) = get_nonneg(&doc, "obs.trace_capacity")? {
            c.obs.trace_capacity = v as usize;
        }
        if let Some(v) = get_bool_strict(&doc, "obs.journal")? {
            c.obs.journal = v;
        }
        if let Some(v) = get_bool_strict(&doc, "obs.collect")? {
            c.obs.collect = v;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        self.transport.validate()?;
        if self.n_params == 0 {
            return Err(anyhow!("live.n_params must be ≥ 1"));
        }
        if crate::coordinator::SyncStrategy::parse(&self.strategy).is_none() {
            return Err(anyhow!(
                "unknown strategy `{}` (netsense|allreduce|topk[:r])",
                self.strategy
            ));
        }
        if self.fault.recv_timeout_ms == 0 || self.fault.probe_timeout_ms == 0 {
            return Err(anyhow!("fault timeouts must be ≥ 1 ms"));
        }
        if self.faults.kill_step(0).is_some() {
            return Err(anyhow!(
                "fault.kill/partial_kill cannot target rank 0 (it carries the report)"
            ));
        }
        if let Some(r) = self.faults.max_rank() {
            if r >= self.transport.n_workers {
                return Err(anyhow!(
                    "fault schedule names rank {r} but transport.n_workers is {}",
                    self.transport.n_workers
                ));
            }
        }
        if self.obs.trace && self.obs.trace_capacity == 0 {
            // A zero-capacity ring would silently record nothing.
            return Err(anyhow!("obs.trace_capacity must be ≥ 1 when obs.trace is on"));
        }
        Ok(())
    }

    /// Materialize the runner options.
    pub fn live_opts(&self) -> LiveOpts {
        LiveOpts {
            n_workers: self.transport.n_workers,
            steps: self.steps,
            n_params: self.n_params,
            strategy: crate::coordinator::SyncStrategy::parse(&self.strategy)
                .expect("validated strategy"),
            backend: self.transport.live_backend(),
            shaping: self.transport.shaping(),
            compute_ms: self.compute_ms,
            seed: self.seed,
            fault: self.fault.clone(),
            faults: self.faults.clone(),
            poller_threads: self.transport.poller_threads,
            obs: ObsOpts {
                trace: self.obs.trace,
                trace_capacity: self.obs.trace_capacity,
                journal: self.obs.journal,
                collect: self.obs.collect,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let c = TrainConfig::from_toml(
            r#"
[train]
model = "vgg16"
strategy = "topk:0.05"
n_workers = 4
max_vtime_s = 120.5
[net]
bandwidth_mbps = 500
prop_delay_ms = 25
"#,
        )
        .unwrap();
        assert_eq!(c.model, "vgg16");
        assert_eq!(c.strategy, "topk:0.05");
        assert_eq!(c.n_workers, 4);
        assert_eq!(c.bandwidth_mbps, 500.0);
        assert_eq!(c.prop_delay_ms, 25);
        assert_eq!(c.max_vtime_s, 120.5);
        // untouched default
        assert_eq!(c.batch_per_worker, 32);
    }

    #[test]
    fn pipeline_section_parses() {
        // Default: pipeline off.
        assert_eq!(TrainConfig::default().pipeline(), None);
        let c = TrainConfig::from_toml(
            r#"
[pipeline]
bucket_kb = 2048
depth = 4
adaptive = false
"#,
        )
        .unwrap();
        assert_eq!(c.bucket_kb, 2048);
        let p = c.pipeline().unwrap();
        assert_eq!(p.bucket_size_bytes, 2048 * 1024);
        assert_eq!(p.pipeline_depth, 4);
        assert!(!p.adaptive);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(TrainConfig::from_toml("[train]\nstrategy = \"bogus\"").is_err());
        assert!(TrainConfig::from_toml("[train]\nn_workers = 0").is_err());
        assert!(TrainConfig::from_toml("[net]\nbandwidth_mbps = -5").is_err());
        assert!(TrainConfig::from_toml("[pipeline]\nbucket_kb = -1").is_err());
        assert!(TrainConfig::from_toml("[pipeline]\ndepth = -2").is_err());
        assert!(TrainConfig::from_toml("not toml at all").is_err());
    }

    #[test]
    fn transport_table_parses_with_shaping_schedule() {
        let c = LiveConfig::from_toml(
            r#"
[transport]
backend = "tcp"
bind = "127.0.0.1:29501"
n_workers = 4
rate_mbps = 64
burst_kb = 16
prop_delay_ms = 4
schedule = [[0.0, 64], [30.0, 8]]

[live]
steps = 50
n_params = 200000
strategy = "netsense"
compute_ms = 10
seed = 7
"#,
        )
        .unwrap();
        assert_eq!(c.transport.backend, "tcp");
        assert_eq!(c.transport.n_workers, 4);
        assert_eq!(c.steps, 50);
        assert_eq!(c.compute_ms, 10);
        let s = c.transport.shaping().unwrap();
        assert_eq!(s.rate_bytes_per_sec, 64.0 * 1e6 / 8.0);
        assert_eq!(s.burst_bytes, 16.0 * 1024.0);
        assert_eq!(s.prop_delay_s, 0.004);
        assert_eq!(s.schedule, vec![(0.0, 8e6), (30.0, 1e6)]);
        assert_eq!(
            c.transport.live_backend(),
            crate::experiments::live::LiveBackend::Tcp {
                bind: "127.0.0.1:29501".to_string()
            }
        );
        // Rate 0 → no shaping.
        let c = LiveConfig::from_toml("[transport]\nrate_mbps = 0").unwrap();
        assert!(c.transport.shaping().is_none());
        // Event-loop pool size: default auto (0), explicit value plumbs
        // through to LiveOpts, negatives rejected.
        assert_eq!(c.transport.poller_threads, 0);
        let c = LiveConfig::from_toml("[transport]\npoller_threads = 3").unwrap();
        assert_eq!(c.transport.poller_threads, 3);
        assert_eq!(c.live_opts().poller_threads, 3);
        assert!(LiveConfig::from_toml("[transport]\npoller_threads = -1").is_err());
    }

    #[test]
    fn transport_table_rejects_unknown_keys() {
        // A typo must fail loudly, not silently default.
        let e = LiveConfig::from_toml("[transport]\nbakend = \"tcp\"").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown key") && msg.contains("bakend"), "{msg}");
        let e = LiveConfig::from_toml("[live]\nstep = 10").unwrap_err();
        assert!(format!("{e:#}").contains("unknown key"), "{e:#}");
        // Nested unknown sub-tables are caught by the same prefix scan.
        assert!(LiveConfig::from_toml("[transport.shaping]\nrate = 5").is_err());
        // A misspelled *section* fails just as loudly — no silent defaults.
        let e = LiveConfig::from_toml("[trasport]\nbackend = \"tcp\"").unwrap_err();
        assert!(format!("{e:#}").contains("unknown section"), "{e:#}");
        assert!(LiveConfig::from_toml("[train]\nmodel = \"resnet18\"").is_err());
    }

    #[test]
    fn transport_table_rejects_bad_values() {
        assert!(LiveConfig::from_toml("[transport]\nbackend = \"udp\"").is_err());
        assert!(LiveConfig::from_toml("[transport]\nn_workers = 0").is_err());
        assert!(LiveConfig::from_toml("[transport]\nrate_mbps = -1").is_err());
        assert!(LiveConfig::from_toml("[live]\nstrategy = \"bogus\"").is_err());
        assert!(LiveConfig::from_toml("[live]\nn_params = 0").is_err());
        // Descending schedule offsets.
        assert!(LiveConfig::from_toml(
            "[transport]\nrate_mbps = 8\nschedule = [[10.0, 4], [5.0, 2]]"
        )
        .is_err());
        // Malformed schedule entries.
        assert!(LiveConfig::from_toml("[transport]\nschedule = [1, 2]").is_err());
        // A schedule without a base rate would be silently unshaped.
        assert!(LiveConfig::from_toml("[transport]\nschedule = [[5.0, 2]]").is_err());
        // Negative integers must error, never wrap through `as usize`.
        assert!(LiveConfig::from_toml("[transport]\nn_workers = -1").is_err());
        assert!(LiveConfig::from_toml("[live]\nsteps = -1").is_err());
        assert!(LiveConfig::from_toml("[live]\nn_params = -1").is_err());
        assert!(LiveConfig::from_toml("[live]\ncompute_ms = -5").is_err());
        // Wrong-typed values must error, never fall back to defaults.
        assert!(LiveConfig::from_toml("[transport]\nbackend = 5").is_err());
        assert!(LiveConfig::from_toml("[transport]\nn_workers = 4.5").is_err());
        assert!(LiveConfig::from_toml("[live]\nsteps = \"50\"").is_err());
    }

    #[test]
    fn fault_table_parses_into_schedule_and_deadlines() {
        let c = LiveConfig::from_toml(
            r#"
[transport]
n_workers = 4

[fault]
recv_timeout_ms = 250
probe_timeout_ms = 1000
kill = [[2, 6]]
stall = [[1, 3, 50]]
flap = [[3, 8, 400]]
duplicate = [[1, 4]]
reorder = [[3, 5]]
partial_kill = [[2, 9, 5]]
"#,
        )
        .unwrap();
        assert_eq!(c.fault.recv_timeout_ms, 250);
        assert_eq!(c.fault.probe_timeout_ms, 1000);
        assert_eq!(c.faults.kills, vec![(2, 6)]);
        assert_eq!(c.faults.stalls, vec![(1, 3, 50)]);
        assert_eq!(c.faults.flaps, vec![(3, 8, 400)]);
        assert_eq!(c.faults.duplicates, vec![(1, 4)]);
        assert_eq!(c.faults.reorders, vec![(3, 5)]);
        assert_eq!(c.faults.partial_kills, vec![(2, 9, 5)]);
        let opts = c.live_opts();
        assert_eq!(opts.fault.recv_timeout_ms, 250);
        assert_eq!(opts.faults.kill_step(2), Some(6));
        // Defaults: empty schedule, 10 s deadlines.
        let c = LiveConfig::from_toml("[transport]\nn_workers = 2").unwrap();
        assert!(c.faults.is_empty());
        assert_eq!(c.fault.recv_timeout_ms, 10_000);
    }

    #[test]
    fn fault_table_rejects_bad_values() {
        // A typo must fail loudly.
        let e = LiveConfig::from_toml("[fault]\nkil = [[1, 2]]").unwrap_err();
        assert!(format!("{e:#}").contains("unknown key"), "{e:#}");
        // Rank 0 carries the report — killing it is a config error.
        assert!(LiveConfig::from_toml("[fault]\nkill = [[0, 3]]").is_err());
        // Ranks must exist.
        assert!(LiveConfig::from_toml(
            "[transport]\nn_workers = 2\n[fault]\nkill = [[5, 3]]"
        )
        .is_err());
        // Malformed rows and negatives.
        assert!(LiveConfig::from_toml("[fault]\nkill = [[1]]").is_err());
        assert!(LiveConfig::from_toml("[fault]\nkill = [1, 2]").is_err());
        assert!(LiveConfig::from_toml("[fault]\nstall = [[1, 2]]").is_err());
        assert!(LiveConfig::from_toml("[fault]\nstall = [[1, -2, 5]]").is_err());
        assert!(LiveConfig::from_toml("[fault]\nflap = [[1, 2, -1]]").is_err());
        // Byzantine rows follow the same rules: a partial kill is a kill
        // (rank 0 must survive), ranks must exist, arity is checked.
        assert!(LiveConfig::from_toml("[fault]\npartial_kill = [[0, 3, 5]]").is_err());
        assert!(LiveConfig::from_toml(
            "[transport]\nn_workers = 2\n[fault]\nreorder = [[5, 3]]"
        )
        .is_err());
        assert!(LiveConfig::from_toml("[fault]\nduplicate = [[1, 2, 3]]").is_err());
        assert!(LiveConfig::from_toml("[fault]\npartial_kill = [[1, 2]]").is_err());
        // Zero deadlines would make every round a recovery.
        assert!(LiveConfig::from_toml("[fault]\nrecv_timeout_ms = 0").is_err());
    }

    #[test]
    fn obs_table_parses_and_rejects_bad_values() {
        // Default: everything off, the always-on registry aside.
        let c = LiveConfig::from_toml("[transport]\nn_workers = 2").unwrap();
        assert!(!c.obs.trace && !c.obs.journal && !c.obs.collect);
        let c = LiveConfig::from_toml(
            r#"
[obs]
trace = true
trace_capacity = 512
journal = true
collect = true
"#,
        )
        .unwrap();
        assert!(c.obs.trace && c.obs.journal && c.obs.collect);
        assert_eq!(c.obs.trace_capacity, 512);
        let opts = c.live_opts();
        assert!(opts.obs.trace && opts.obs.journal && opts.obs.collect);
        assert_eq!(opts.obs.trace_capacity, 512);
        assert!(LiveConfig::from_toml("[obs]\ncollect = \"on\"").is_err());
        // A typo must fail loudly.
        let e = LiveConfig::from_toml("[obs]\ntracing = true").unwrap_err();
        assert!(format!("{e:#}").contains("unknown key"), "{e:#}");
        // Wrong types and a useless zero-capacity ring are errors.
        assert!(LiveConfig::from_toml("[obs]\ntrace = 1").is_err());
        assert!(LiveConfig::from_toml("[obs]\njournal = \"yes\"").is_err());
        assert!(LiveConfig::from_toml("[obs]\ntrace_capacity = -1").is_err());
        assert!(LiveConfig::from_toml("[obs]\ntrace = true\ntrace_capacity = 0").is_err());
    }

    #[test]
    fn live_exemplar_config_file_parses() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/live.toml");
        let c = LiveConfig::from_toml_file(&path).unwrap();
        assert_eq!(c.transport.backend, "tcp");
        assert!(c.transport.shaping().is_some());
        c.live_opts(); // must materialize without panicking
    }

    #[test]
    fn elastic_exemplar_config_file_parses() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/elastic.toml");
        let c = LiveConfig::from_toml_file(&path).unwrap();
        assert_eq!(c.faults.kills, vec![(2, 12)]);
        assert_eq!(c.faults.flaps, vec![(3, 24, 400)]);
        assert_eq!(c.fault.recv_timeout_ms, 250);
        assert_eq!(c.transport.n_workers, 4);
        c.live_opts(); // must materialize without panicking
    }
}
