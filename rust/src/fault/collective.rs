//! Degraded collectives: a ring all-gather over the *live* membership
//! that survives dead ranks, stragglers, and flapping links.
//!
//! Every payload travels inside a 9-byte **envelope** —
//! `[kind u8][epoch u32 le][step u32 le]` — so a receiver can tell this
//! epoch's data from a stale frame of an aborted round, a data frame
//! from a recovery probe, and — the step tag — *this round's* data from
//! a neighboring round's. Data frames double as heartbeats (receiving
//! one clears any suspicion of the sender).
//!
//! The exchange protocol, per training step:
//!
//! 1. **Attempt** a ring all-gather over the live ring at the current
//!    epoch ([`super::Membership::live_ring`]). Stale-epoch data frames
//!    are discarded on arrival.
//! 2. On a recv deadline or peer disconnect, the observer **suspects**
//!    its ring predecessor and aborts. On receiving a [`FrameKind::Probe`]
//!    it aborts immediately (a peer already detected trouble) — this is
//!    how one rank's timeout propagates around the ring in channel time
//!    instead of one timeout per hop. A rank whose *own* round overran the
//!    round budget (one `recv_timeout` — the same rule every peer applies
//!    to it, so both sides of a slow link reach the same verdict) aborts
//!    too, even if every frame it needed was already buffered: a straggler
//!    that limped home late must join the recovery its peers are already
//!    running, or its view of the round would diverge from theirs.
//!    Corollary: `recv_timeout` must comfortably exceed a healthy round's
//!    duration — it is a *round* budget, not a per-hop one.
//! 3. **Recovery**: every survivor sends a probe to every rank it still
//!    considers live and awaits one from each (per-peer FIFO guarantees a
//!    peer's probe precedes its replay data, so draining up to the probe
//!    never eats next-epoch frames). Ranks that fail to answer within the
//!    probe deadline are dead. The killed rank answers *nobody*, so every
//!    survivor removes the same set and [`super::Membership::begin_epoch`]
//!    lands them on the same epoch — agreement without a coordinator.
//! 4. **Replay** the round over the rebuilt ring at the new epoch. The
//!    caller's payload is untouched (compression and error feedback ran
//!    before the exchange), so the replay is bit-deterministic.
//!
//! A recovery that finds nobody dead (a flapping link healed in time)
//! still bumps the epoch — the replay's frames must outrank the aborted
//! round's stragglers.
//!
//! The step tag closes the one hole the round budget leaves: a rank that
//! sent everything its peers needed, then was descheduled past the
//! budget, aborts *alone* while its peers complete and move on. Its
//! replay would otherwise gather the peers' next-round payloads as this
//! round's (a silent one-round skew, forever). With the tag, receiving
//! same-epoch data for a *different* step is proof this rank fell behind
//! the group — it fails loudly ([`ElasticExchange::round`] errors), the
//! peers' next recovery removes it, and the survivors continue.

use super::membership::{LiveRing, Membership};
use super::FaultConfig;
use crate::obs;
use crate::transport::Transport;
use crate::util::error::{anyhow, Result};
use std::time::{Duration, Instant};

/// Envelope bytes prepended to every elastic payload (kind + epoch +
/// step).
pub const ENVELOPE_OVERHEAD: usize = 9;

/// What an envelope carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// A collective payload of the tagged epoch + step (doubles as a
    /// heartbeat).
    Data,
    /// A recovery probe: "I aborted the round at this epoch — are you
    /// alive?" Answered by the receiver's own probe of the same recovery.
    Probe,
    /// A serialized telemetry payload for the end-of-run gather
    /// ([`crate::obs::collect`]). Never seen mid-round; the data loop
    /// fences it like any stale frame.
    Obs,
    /// A clock-offset ping/pong (rank 0's `t0`, or a peer's own clock)
    /// preceding the telemetry payload. Fenced mid-round like `Obs`.
    Clock,
}

/// Append the 9-byte envelope header (zero allocations once `out` has
/// capacity — the membership-checked send path stays on the PR-3
/// zero-alloc budget).
pub fn write_envelope(kind: FrameKind, epoch: u32, step: u32, out: &mut Vec<u8>) {
    out.push(match kind {
        FrameKind::Data => 0,
        FrameKind::Probe => 1,
        FrameKind::Obs => 2,
        FrameKind::Clock => 3,
    });
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&step.to_le_bytes());
}

/// Split an enveloped frame into `(kind, epoch, step, payload)`.
pub fn parse_envelope(buf: &[u8]) -> Result<(FrameKind, u32, u32, &[u8])> {
    if buf.len() < ENVELOPE_OVERHEAD {
        return Err(anyhow!("short envelope: {} bytes", buf.len()));
    }
    let kind = match buf[0] {
        0 => FrameKind::Data,
        1 => FrameKind::Probe,
        2 => FrameKind::Obs,
        3 => FrameKind::Clock,
        k => return Err(anyhow!("unknown envelope kind {k}")),
    };
    let epoch = u32::from_le_bytes(buf[1..5].try_into().unwrap());
    let step = u32::from_le_bytes(buf[5..9].try_into().unwrap());
    Ok((kind, epoch, step, &buf[ENVELOPE_OVERHEAD..]))
}

/// What one elastic exchange round produced (owning form — see
/// [`ElasticExchange::round`]). The zero-copy reduce path
/// ([`ElasticExchange::round_reduce`]) returns [`RoundStats`] instead and
/// hands the payloads to a reducer as borrowed slices.
#[derive(Clone, Debug)]
pub struct ElasticRound {
    /// Payload per absolute rank; `None` for ranks outside the live set
    /// when the round completed.
    pub blocks: Vec<Option<Vec<u8>>>,
    /// Start-to-finish wall time at this rank, recoveries included — the
    /// transfer-completion observable the sensing controller consumes.
    pub elapsed: Duration,
    /// Payload bytes pushed into the ring (envelopes included, aborted
    /// attempts included).
    pub sent_bytes: u64,
    /// Epoch bumps performed while completing this round.
    pub recoveries: u64,
    /// Did any deadline or abort fire? This is the `lost` flag the
    /// Algorithm-1 controller's backoff consumes.
    pub lost: bool,
    /// Epoch the round completed at.
    pub epoch: u64,
    /// Well-formed frames discarded by the epoch/step fencing (stale
    /// rounds, replayed duplicates, withheld-then-released reorders) —
    /// each such frame is dropped exactly once, here.
    pub dropped_stale: u64,
    /// Frames that failed envelope parse (torn writes, line noise) —
    /// rejected by parse, never by trust.
    pub dropped_garbage: u64,
}

/// [`ElasticRound`] minus the payloads: what
/// [`ElasticExchange::round_reduce`] returns after the reducer has
/// consumed every block in place.
#[derive(Clone, Copy, Debug)]
pub struct RoundStats {
    /// Start-to-finish wall time at this rank, recoveries included — the
    /// transfer-completion observable the sensing controller consumes.
    pub elapsed: Duration,
    /// Payload bytes pushed into the ring (envelopes included, aborted
    /// attempts included).
    pub sent_bytes: u64,
    /// Epoch bumps performed while completing this round.
    pub recoveries: u64,
    /// Did any deadline or abort fire? This is the `lost` flag the
    /// Algorithm-1 controller's backoff consumes.
    pub lost: bool,
    /// Epoch the round completed at.
    pub epoch: u64,
    /// Blocks handed to the reducer (own payload included) — the live
    /// ranks present when the round completed.
    pub n_blocks: usize,
    /// Well-formed frames discarded by the epoch/step fencing (stale
    /// rounds, replayed duplicates, withheld-then-released reorders) —
    /// each such frame is dropped exactly once, here.
    pub dropped_stale: u64,
    /// Frames that failed envelope parse (torn writes, line noise) —
    /// rejected by parse, never by trust.
    pub dropped_garbage: u64,
}

/// Why an attempt stopped early.
enum AttemptEnd {
    /// Deadline / disconnect / peer probe: recover and replay.
    Abort(Abort),
    /// Same-epoch data for a different step (or a future epoch): this
    /// rank fell out of lockstep with the group — unrecoverable locally.
    Skew { peer_epoch: u32, peer_step: u32 },
}

/// An abort's bookkeeping.
struct Abort {
    /// The ring predecessor that missed its deadline (None when the abort
    /// came from a peer's probe).
    suspect: Option<usize>,
    /// A probe consumed inside the data loop — already counts as that
    /// peer's recovery answer.
    probe_from: Option<usize>,
}

/// Reusable elastic-exchange state for one endpoint: scratch buffers and
/// the per-recovery probe bookkeeping, plus the live ring cache (rebuilt
/// only on epoch change).
///
/// §Perf (receive-side zero-copy): the round's payloads live in
/// `blocks` — one reusable enveloped-frame buffer per absolute rank,
/// refilled in place every round. Incoming frames land in `recv_buf`
/// ([`crate::transport::Transport::recv_into`]) and are *swapped* into
/// their origin's slot, so the buffers rotate and steady state moves
/// payloads without a single heap allocation on this endpoint. Stored
/// frames keep their envelope: forwarding a block around the ring re-sends
/// the stored bytes verbatim (the envelope of a valid frame is exactly
/// what this rank would re-write), and the reducer sees the
/// envelope-stripped tail as a borrowed slice.
pub struct ElasticExchange {
    cfg: FaultConfig,
    ring: LiveRing,
    /// Reused probe frame.
    probe: Vec<u8>,
    /// Per-rank: probe already consumed during the aborted data round.
    probes_seen: Vec<bool>,
    /// Per-origin enveloped frames of the round in progress (reused
    /// across rounds; swapped with `recv_buf` on receipt).
    blocks: Vec<Vec<u8>>,
    /// `present[r]`: `blocks[r]` holds rank `r`'s frame for this attempt.
    present: Vec<bool>,
    /// Reused receive staging buffer.
    recv_buf: Vec<u8>,
    /// Fenced-frame drops of the round in progress (reset per round,
    /// snapshotted into [`RoundStats::dropped_stale`]).
    dropped_stale: u64,
    /// Parse-failure drops of the round in progress (reset per round,
    /// snapshotted into [`RoundStats::dropped_garbage`]).
    dropped_garbage: u64,
}

impl ElasticExchange {
    pub fn new(m: &Membership, cfg: FaultConfig) -> ElasticExchange {
        ElasticExchange {
            cfg,
            ring: m.live_ring(),
            probe: Vec::new(),
            probes_seen: vec![false; m.world()],
            blocks: (0..m.world()).map(|_| Vec::new()).collect(),
            present: vec![false; m.world()],
            recv_buf: Vec::new(),
            dropped_stale: 0,
            dropped_garbage: 0,
        }
    }

    /// The ring in force (test observability).
    pub fn ring(&self) -> &LiveRing {
        &self.ring
    }

    /// One gradient-exchange round at training step `step`, owning form:
    /// all-gather `payload` across the live group, recovering and
    /// replaying on failures, and return every block as an owned vector
    /// (envelope stripped, indexed by absolute rank). A thin wrapper over
    /// [`Self::round_reduce`] — hot paths that aggregate in place use
    /// that directly and skip these per-block allocations.
    pub fn round(
        &mut self,
        t: &mut dyn Transport,
        m: &mut Membership,
        step: u32,
        payload: &[u8],
    ) -> Result<ElasticRound> {
        let mut blocks: Vec<Option<Vec<u8>>> = vec![None; m.world()];
        let stats = self.round_reduce(t, m, step, payload, |origin, body| {
            blocks[origin] = Some(body.to_vec());
            Ok(())
        })?;
        Ok(ElasticRound {
            blocks,
            elapsed: stats.elapsed,
            sent_bytes: stats.sent_bytes,
            recoveries: stats.recoveries,
            lost: stats.lost,
            epoch: stats.epoch,
            dropped_stale: stats.dropped_stale,
            dropped_garbage: stats.dropped_garbage,
        })
    }

    /// One gradient-exchange round at training step `step`, zero-copy
    /// form: all-gather `payload` across the live group, recovering and
    /// replaying on failures, then hand each live rank's payload to
    /// `reduce` as a **borrowed, envelope-stripped slice** — no owned
    /// byte vectors leave the exchange (the fused receive path scatters
    /// straight into its dense accumulator from here).
    ///
    /// Replay semantics are preserved bit-exactly: the reducer runs only
    /// after an attempt *completes* at the final epoch, exactly once per
    /// present rank, in ascending rank order — an aborted attempt's
    /// partial frames are overwritten by the replay and never reach the
    /// reducer. The slices borrow the exchange's reusable round buffers
    /// and are valid only for the duration of the callback.
    ///
    /// Errors when this endpoint itself is broken (killed), fell out of
    /// lockstep (round skew — see module docs), recovery keeps failing
    /// past any plausible schedule, or the reducer rejects a payload (a
    /// corrupt frame surfaces as the reducer's named error; the
    /// accumulator state is then unspecified and the round must not be
    /// consumed).
    pub fn round_reduce<F>(
        &mut self,
        t: &mut dyn Transport,
        m: &mut Membership,
        step: u32,
        payload: &[u8],
        mut reduce: F,
    ) -> Result<RoundStats>
    where
        F: FnMut(usize, &[u8]) -> Result<()>,
    {
        let t0 = Instant::now();
        let mut sent = 0u64;
        let mut recoveries = 0u64;
        let mut lost = false;
        self.probes_seen.iter_mut().for_each(|p| *p = false);
        self.dropped_stale = 0;
        self.dropped_garbage = 0;
        loop {
            match self.attempt(t, m, step, payload, &mut sent) {
                Ok(()) => {
                    let mut n_blocks = 0usize;
                    for origin in 0..m.world() {
                        if self.present[origin] {
                            reduce(origin, &self.blocks[origin][ENVELOPE_OVERHEAD..])?;
                            n_blocks += 1;
                        }
                    }
                    let elapsed = t0.elapsed();
                    // Telemetry: relaxed atomic bumps on the global
                    // registry — allocation-free (the zero-alloc gates
                    // below run with these live).
                    let om = obs::hot();
                    let elapsed_us = elapsed.as_micros() as u64;
                    om.rounds_total.inc();
                    om.bytes_sent_total.add(sent);
                    om.round_us.observe(elapsed_us);
                    if recoveries > 0 {
                        om.recoveries_total.add(recoveries);
                        om.recovery_us.observe(elapsed_us);
                    }
                    if lost {
                        om.lost_rounds_total.inc();
                    }
                    if self.dropped_stale > 0 {
                        om.dropped_stale_total.add(self.dropped_stale);
                    }
                    if self.dropped_garbage > 0 {
                        om.dropped_garbage_total.add(self.dropped_garbage);
                    }
                    return Ok(RoundStats {
                        elapsed,
                        sent_bytes: sent,
                        recoveries,
                        lost,
                        epoch: m.epoch(),
                        n_blocks,
                        dropped_stale: self.dropped_stale,
                        dropped_garbage: self.dropped_garbage,
                    });
                }
                Err(AttemptEnd::Skew {
                    peer_epoch,
                    peer_step,
                }) => {
                    return Err(anyhow!(
                        "rank {}: round skew — peer at epoch {peer_epoch}/step {peer_step} \
                         vs local {}/{step}; this rank fell behind the group and cannot \
                         rejoin in place (resume from a checkpoint)",
                        m.self_rank(),
                        m.epoch()
                    ));
                }
                Err(AttemptEnd::Abort(abort)) => {
                    lost = true;
                    recoveries += 1;
                    if recoveries > m.world() as u64 + 2 {
                        return Err(anyhow!(
                            "rank {}: giving up after {recoveries} recoveries in one round",
                            m.self_rank()
                        ));
                    }
                    if let Some(r) = abort.suspect {
                        m.suspect(r);
                    }
                    if let Some(r) = abort.probe_from {
                        self.probes_seen[r] = true;
                    }
                    let dead = self.probe_phase(t, m, step)?;
                    m.begin_epoch(&dead);
                    self.ring = m.live_ring();
                }
            }
        }
    }

    /// One all-gather attempt over the current live ring. On `Ok` the
    /// enveloped frames sit in `self.blocks` (flagged by `self.present`);
    /// `Err` is an abort or a detected round skew. No allocations in
    /// steady state: frames land in reused buffers via
    /// [`crate::transport::Transport::recv_into`] and rotate by swap.
    fn attempt(
        &mut self,
        t: &mut dyn Transport,
        m: &mut Membership,
        step: u32,
        payload: &[u8],
        sent: &mut u64,
    ) -> std::result::Result<(), AttemptEnd> {
        let ln = self.ring.len();
        let epoch = m.epoch() as u32;
        let me = m.self_rank();
        self.present.iter_mut().for_each(|p| *p = false);
        let own = &mut self.blocks[me];
        own.clear();
        write_envelope(FrameKind::Data, epoch, step, own);
        own.extend_from_slice(payload);
        self.present[me] = true;
        if self.ring.is_solo() {
            return Ok(());
        }
        // The whole round must finish within one recv budget — the same
        // deadline every peer applies to us, so a delay that makes *them*
        // abort makes *us* abort too (a straggler that limped home late
        // from buffered frames must join the recovery; see module docs).
        let round_deadline = self.cfg.recv_timeout();
        let t_start = Instant::now();
        let succ = self.ring.succ();
        let pred = self.ring.pred();
        for p in 0..ln - 1 {
            // Forward the block that originated `p` ring hops back — the
            // stored frame re-sends verbatim (its envelope is exactly
            // this epoch/step's, validated on receipt).
            let origin = self.ring.rank_at(self.ring.pos + ln - p);
            debug_assert!(self.present[origin], "origin block in hand");
            *sent += self.blocks[origin].len() as u64;
            if t.send(succ, &self.blocks[origin]).is_err() {
                return Err(AttemptEnd::Abort(Abort {
                    suspect: Some(succ),
                    probe_from: None,
                }));
            }
            let incoming_origin = self.ring.rank_at(self.ring.pos + 2 * ln - 1 - p);
            loop {
                if t.recv_into(pred, &mut self.recv_buf).is_err() {
                    return Err(AttemptEnd::Abort(Abort {
                        suspect: Some(pred),
                        probe_from: None,
                    }));
                }
                match parse_envelope(&self.recv_buf) {
                    Ok((FrameKind::Data, e, s, _)) if e == epoch && s == step => {
                        m.heartbeat(pred);
                        // Keep the whole enveloped frame: forwarding
                        // re-sends it as-is, the reducer strips the
                        // envelope. Swap, don't copy.
                        std::mem::swap(&mut self.recv_buf, &mut self.blocks[incoming_origin]);
                        self.present[incoming_origin] = true;
                        break;
                    }
                    Ok((FrameKind::Data, e, _, _)) if e < epoch => {
                        // Stale round (aborted-attempt leftovers, replayed
                        // duplicates): fence it, count it, keep waiting.
                        self.dropped_stale += 1;
                        continue;
                    }
                    Ok((FrameKind::Data, e, s, _)) if e == epoch && s < step => {
                        // A peer that fell behind is replaying an older
                        // step; it will detect the skew and self-fence —
                        // drop its doomed frames and keep waiting (our
                        // deadline then drives the recovery that removes
                        // it).
                        self.dropped_stale += 1;
                        continue;
                    }
                    Ok((FrameKind::Data, e, s, _)) => {
                        // A future step (same epoch) or a future epoch:
                        // the group moved on without us — lockstep is
                        // broken and cannot be repaired locally.
                        return Err(AttemptEnd::Skew {
                            peer_epoch: e,
                            peer_step: s,
                        });
                    }
                    Ok((FrameKind::Probe, _, _, _)) => {
                        return Err(AttemptEnd::Abort(Abort {
                            suspect: None,
                            probe_from: Some(pred),
                        }));
                    }
                    Ok((FrameKind::Obs | FrameKind::Clock, _, _, _)) => {
                        // Telemetry-gather frames belong strictly after
                        // the training loop; one leaking into a round
                        // (e.g. a chaos-duplicated replay) is fenced like
                        // any stale frame.
                        self.dropped_stale += 1;
                        continue;
                    }
                    Err(_) => {
                        // Garbage frame (torn write, line noise): rejected
                        // by parse — drop, count, keep waiting.
                        self.dropped_garbage += 1;
                        continue;
                    }
                }
            }
        }
        if t_start.elapsed() > round_deadline {
            return Err(AttemptEnd::Abort(Abort {
                suspect: None,
                probe_from: None,
            }));
        }
        Ok(())
    }

    /// The all-to-all recovery probe: send one probe to every live peer,
    /// await one from each (unless already consumed in the data loop).
    /// Returns the ranks that failed to answer — the dead set every
    /// survivor agrees on.
    fn probe_phase(
        &mut self,
        t: &mut dyn Transport,
        m: &Membership,
        step: u32,
    ) -> Result<Vec<usize>> {
        let me = m.self_rank();
        t.set_recv_timeout(self.cfg.probe_timeout());
        self.probe.clear();
        write_envelope(FrameKind::Probe, m.epoch() as u32, step, &mut self.probe);
        let mut dead = Vec::new();
        for r in 0..m.world() {
            if r == me || !m.is_live(r) {
                continue;
            }
            if t.send(r, &self.probe).is_err() {
                dead.push(r);
            }
        }
        for r in 0..m.world() {
            if r == me || !m.is_live(r) || dead.contains(&r) || self.probes_seen[r] {
                continue;
            }
            let alive = loop {
                match t.recv_into(r, &mut self.recv_buf) {
                    Ok(()) => match parse_envelope(&self.recv_buf) {
                        Ok((FrameKind::Probe, _, _, _)) => break true,
                        Ok(_) => {
                            // Pre-abort data (including a reordering
                            // peer's released backlog): drain past it,
                            // counted once.
                            self.dropped_stale += 1;
                            continue;
                        }
                        Err(_) => {
                            self.dropped_garbage += 1;
                            continue;
                        }
                    },
                    Err(_) => break false, // deadline or disconnect
                }
            };
            if !alive {
                dead.push(r);
            }
        }
        t.set_recv_timeout(self.cfg.recv_timeout());
        self.probes_seen.iter_mut().for_each(|p| *p = false);
        Ok(dead)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::injector::{FaultInjector, FaultSpec};
    use crate::transport::LoopbackTransport;

    fn cfg_ms(recv: u64, probe: u64) -> FaultConfig {
        FaultConfig {
            recv_timeout_ms: recv,
            probe_timeout_ms: probe,
        }
    }

    #[test]
    fn envelope_roundtrip_and_rejects() {
        let mut buf = Vec::new();
        write_envelope(FrameKind::Data, 7, 42, &mut buf);
        buf.extend_from_slice(b"payload");
        let (k, e, s, body) = parse_envelope(&buf).unwrap();
        assert_eq!((k, e, s, body), (FrameKind::Data, 7, 42, &b"payload"[..]));
        let mut probe = Vec::new();
        write_envelope(FrameKind::Probe, u32::MAX, 0, &mut probe);
        let (k, e, _, body) = parse_envelope(&probe).unwrap();
        assert_eq!((k, e), (FrameKind::Probe, u32::MAX));
        assert!(body.is_empty());
        for kind in [FrameKind::Obs, FrameKind::Clock] {
            let mut buf = Vec::new();
            write_envelope(kind, 0, 0, &mut buf);
            buf.extend_from_slice(&77u64.to_le_bytes());
            let (k, _, _, body) = parse_envelope(&buf).unwrap();
            assert_eq!(k, kind);
            assert_eq!(body, 77u64.to_le_bytes());
        }
        assert!(parse_envelope(&[0, 1]).is_err());
        assert!(parse_envelope(&[0u8; ENVELOPE_OVERHEAD - 1]).is_err());
        assert!(parse_envelope(&[9, 0, 0, 0, 0, 0, 0, 0, 0]).is_err());
    }

    /// Run one elastic round on every rank of a loopback mesh with the
    /// given per-rank fault specs; returns each rank's outcome.
    fn run_mesh_round(
        n: usize,
        cfg: FaultConfig,
        specs: Vec<Vec<FaultSpec>>,
        steps: usize,
    ) -> Vec<Option<Vec<ElasticRound>>> {
        let mesh = LoopbackTransport::mesh(n);
        let handles: Vec<_> = mesh
            .into_iter()
            .zip(specs)
            .map(|(t, spec)| {
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    let rank = t.rank();
                    let mut t = FaultInjector::new(Box::new(t), spec);
                    t.set_recv_timeout(cfg.recv_timeout());
                    let mut m = Membership::new(rank, n);
                    let mut ex = ElasticExchange::new(&m, cfg);
                    let mut rounds = Vec::new();
                    for step in 0..steps {
                        t.on_step(step);
                        if t.is_killed() {
                            return None;
                        }
                        let payload = vec![rank as u8; 10 + rank];
                        match ex.round(&mut t, &mut m, step as u32, &payload) {
                            // A rank killed *mid-round* (torn write) can
                            // still "complete" the round solo — its probe
                            // sends all fail, so it removes everyone and
                            // replays alone. That round is a dead rank's
                            // hallucination: discard it, like the live
                            // worker loop does.
                            Ok(_) if t.is_killed() => return None,
                            Ok(r) => rounds.push(r),
                            Err(_) if t.is_killed() => return None,
                            Err(e) => panic!("rank {rank}: {e}"),
                        }
                    }
                    Some(rounds)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn healthy_group_matches_plain_allgather() {
        let outs = run_mesh_round(4, cfg_ms(2_000, 2_000), vec![Vec::new(); 4], 2);
        for out in outs.iter() {
            let rounds = out.as_ref().expect("no one dies");
            for r in rounds {
                assert_eq!(r.recoveries, 0);
                assert!(!r.lost);
                assert_eq!(r.epoch, 0);
                for (origin, b) in r.blocks.iter().enumerate() {
                    assert_eq!(b.as_deref(), Some(&vec![origin as u8; 10 + origin][..]));
                }
            }
        }
    }

    #[test]
    fn killed_rank_is_removed_and_survivors_agree() {
        let n = 4;
        let mut specs = vec![Vec::new(); n];
        specs[2] = vec![FaultSpec::KillAtStep { step: 1 }];
        let outs = run_mesh_round(n, cfg_ms(120, 600), specs, 3);
        assert!(outs[2].is_none(), "rank 2 must die");
        for (rank, out) in outs.iter().enumerate() {
            if rank == 2 {
                continue;
            }
            let rounds = out.as_ref().unwrap_or_else(|| panic!("rank {rank} died"));
            assert_eq!(rounds.len(), 3);
            // Step 0: full group.
            assert_eq!(rounds[0].epoch, 0);
            assert!(rounds[0].blocks[2].is_some());
            // Step 1: abort, one recovery, rank 2 gone.
            assert_eq!(rounds[1].recoveries, 1, "rank {rank}");
            assert!(rounds[1].lost);
            assert_eq!(rounds[1].epoch, 1);
            assert!(rounds[1].blocks[2].is_none());
            // Step 2: clean ring of 3.
            assert_eq!(rounds[2].recoveries, 0);
            assert_eq!(rounds[2].epoch, 1);
            let present: Vec<usize> = rounds[2]
                .blocks
                .iter()
                .enumerate()
                .filter_map(|(i, b)| b.as_ref().map(|_| i))
                .collect();
            assert_eq!(present, vec![0, 1, 3]);
        }
    }

    #[test]
    fn flap_recovers_without_deaths() {
        // Rank 1's link goes down for 300 ms with a 100 ms recv deadline:
        // peers abort and probe; by probe time the link has healed, so the
        // epoch bumps with zero deaths and the replay includes everyone.
        let n = 3;
        let mut specs = vec![Vec::new(); n];
        specs[1] = vec![FaultSpec::FlapAtStep { step: 1, down_ms: 300 }];
        let outs = run_mesh_round(n, cfg_ms(100, 2_000), specs, 3);
        for (rank, out) in outs.iter().enumerate() {
            let rounds = out.as_ref().unwrap_or_else(|| panic!("rank {rank} died"));
            assert_eq!(rounds[1].epoch, rounds[1].recoveries, "epoch == recoveries");
            assert!(rounds[1].lost, "rank {rank} must see the outage");
            // Everyone still present after the flap.
            for r in rounds {
                let live = r.blocks.iter().filter(|b| b.is_some()).count();
                assert_eq!(live, n, "rank {rank}: flap must not kill anyone");
            }
            // Final epochs agree across ranks.
            assert_eq!(rounds[2].epoch, outs[0].as_ref().unwrap()[2].epoch);
        }
    }

    #[test]
    fn short_stall_is_absorbed_without_recovery() {
        let n = 3;
        let mut specs = vec![Vec::new(); n];
        specs[1] = vec![FaultSpec::StallAtStep { step: 1, stall_ms: 40 }];
        let outs = run_mesh_round(n, cfg_ms(1_000, 1_000), specs, 3);
        for out in outs.iter() {
            for r in out.as_ref().unwrap() {
                assert_eq!(r.recoveries, 0, "a sub-deadline straggler is just a slow round");
                assert_eq!(r.epoch, 0);
            }
        }
    }

    /// `round_reduce` must deliver exactly the bytes `round` does — same
    /// origins, same payloads, same order — while borrowing instead of
    /// owning.
    #[test]
    fn round_reduce_matches_owned_round_block_for_block() {
        let n = 4;
        let mesh = LoopbackTransport::mesh(n);
        let handles: Vec<_> = mesh
            .into_iter()
            .map(|mut t| {
                std::thread::spawn(move || {
                    let rank = t.rank();
                    t.set_recv_timeout(cfg_ms(2_000, 2_000).recv_timeout());
                    let mut m = Membership::new(rank, n);
                    let mut ex = ElasticExchange::new(&m, cfg_ms(2_000, 2_000));
                    let payload = vec![rank as u8; 20 + rank];
                    // Step 0 via the owned API, step 1 via the reducer:
                    // both must see every origin's payload.
                    let owned = ex.round(&mut t, &mut m, 0, &payload).unwrap();
                    let mut reduced: Vec<(usize, Vec<u8>)> = Vec::new();
                    let stats = ex
                        .round_reduce(&mut t, &mut m, 1, &payload, |origin, body| {
                            reduced.push((origin, body.to_vec()));
                            Ok(())
                        })
                        .unwrap();
                    (owned, reduced, stats)
                })
            })
            .collect();
        for h in handles {
            let (owned, reduced, stats) = h.join().unwrap();
            assert_eq!(stats.n_blocks, n);
            assert_eq!(reduced.len(), n);
            assert!(!stats.lost);
            for (i, (origin, body)) in reduced.iter().enumerate() {
                assert_eq!(*origin, i, "reducer must run in ascending rank order");
                assert_eq!(
                    owned.blocks[i].as_deref(),
                    Some(&body[..]),
                    "origin {i}: reduced payload diverged from owned round"
                );
            }
        }
    }

    /// A reducer error (e.g. a corrupt payload rejected by the fused
    /// decode) propagates out of `round_reduce` as a named error instead
    /// of panicking.
    #[test]
    fn reducer_error_propagates() {
        let n = 2;
        let mesh = LoopbackTransport::mesh(n);
        let handles: Vec<_> = mesh
            .into_iter()
            .map(|mut t| {
                std::thread::spawn(move || {
                    let rank = t.rank();
                    t.set_recv_timeout(cfg_ms(2_000, 2_000).recv_timeout());
                    let mut m = Membership::new(rank, n);
                    let mut ex = ElasticExchange::new(&m, cfg_ms(2_000, 2_000));
                    ex.round_reduce(&mut t, &mut m, 0, &[rank as u8; 4], |origin, _| {
                        if origin == 1 {
                            Err(crate::util::error::anyhow!("corrupt payload from {origin}"))
                        } else {
                            Ok(())
                        }
                    })
                })
            })
            .collect();
        for h in handles {
            let e = h.join().unwrap().unwrap_err();
            assert!(format!("{e}").contains("corrupt payload"), "{e}");
        }
    }

    /// The receive-side mirror of the send gate below (ISSUE satellite):
    /// a full live loopback round's data plane — fused compress into the
    /// enveloped wire buffer, the wire bytes handed across as borrowed
    /// enveloped frames (exactly what `round_reduce` hands its reducer
    /// after the swap-rotated receive), envelope strip, fused
    /// decode-reduce into the dense accumulator — performs ZERO heap
    /// allocations per step once warm. Channel internals (mpsc node
    /// boxes) are the transport's own cost and sit outside the data
    /// plane; every payload-proportional allocation is covered here.
    ///
    /// Telemetry is ON throughout (obs acceptance criterion): every step
    /// records spans and hot-registry metrics exactly as the live worker
    /// loop does, and the step still allocates nothing.
    #[test]
    fn steady_state_receive_decode_reduce_is_allocation_free() {
        use crate::compress::{
            decode_reduce_into, CompressionConfig, NetSenseCompressor, Workspace,
        };
        use crate::obs::{hot, Tracer};
        use crate::testing::alloc::thread_alloc_count;
        use crate::util::rng::Pcg64;

        let n = 20_000;
        let peers = 4usize;
        let mut r = Pcg64::seeded(9);
        let mut w = vec![0f32; n];
        r.fill_normal_f32(&mut w, 0.0, 0.1);
        // One compressor + drifting gradient per simulated peer.
        let mut comps: Vec<NetSenseCompressor> = (0..peers)
            .map(|_| NetSenseCompressor::new(n, CompressionConfig::default()))
            .collect();
        let mut grads: Vec<Vec<f32>> = (0..peers)
            .map(|p| {
                let mut g = vec![0f32; n];
                Pcg64::seeded(100 + p as u64).fill_normal_f32(&mut g, 0.0, 1.0);
                g
            })
            .collect();
        let mut ws = Workspace::with_capacity(n);
        // Reused enveloped wire frames (what the exchange's round buffers
        // hold) and the reused dense accumulator.
        let mut wires: Vec<Vec<u8>> = (0..peers).map(|_| Vec::new()).collect();
        let mut acc = vec![0f32; n];
        let m = Membership::new(0, peers);
        // Telemetry on: a live-loop-sized tracer plus the hot registry
        // (registration allocates once, here — before the measured loop).
        let mut tracer = Tracer::new(0, 512, std::time::Instant::now());
        let om = hot();
        let mut step_no = 0u32;
        let mut step = |comps: &mut [NetSenseCompressor],
                        grads: &mut [Vec<f32>],
                        wires: &mut [Vec<u8>],
                        ws: &mut Workspace,
                        acc: &mut [f32],
                        r: &mut Pcg64,
                        tracer: &mut Tracer,
                        step_no: &mut u32| {
            let sp_step = tracer.start("step", *step_no);
            // Send half, per peer: envelope + fused compress.
            for ((comp, g), wire) in comps.iter_mut().zip(grads.iter_mut()).zip(wires.iter_mut())
            {
                for x in g.iter_mut() {
                    *x += 0.05 * r.normal() as f32;
                }
                let sp_c = tracer.start("compress", *step_no);
                let t_c = std::time::Instant::now();
                wire.clear();
                write_envelope(FrameKind::Data, m.epoch() as u32, *step_no, wire);
                comp.compress_payload_into(g, &w, 0.1, ws, wire);
                om.compress_ns.observe(t_c.elapsed().as_nanos() as u64);
                om.bytes_sent_total.add(wire.len() as u64);
                tracer.end(sp_c);
            }
            // Receive half: envelope strip + fused decode-reduce, in rank
            // order — byte-for-byte what round_reduce hands the reducer.
            acc.iter_mut().for_each(|a| *a = 0.0);
            for wire in wires.iter() {
                let sp_d = tracer.start("decode", *step_no);
                let t_d = std::time::Instant::now();
                let (kind, e, s, body) = parse_envelope(wire).expect("self-built envelope");
                assert_eq!((kind, e, s), (FrameKind::Data, m.epoch() as u32, *step_no));
                decode_reduce_into(body, acc).expect("self-encoded payload decodes");
                om.decode_ns.observe(t_d.elapsed().as_nanos() as u64);
                tracer.end(sp_d);
            }
            om.rounds_total.inc();
            tracer.end(sp_step);
            *step_no += 1;
        };
        for _ in 0..40 {
            step(
                &mut comps, &mut grads, &mut wires, &mut ws, &mut acc, &mut r, &mut tracer,
                &mut step_no,
            );
        }
        let before = thread_alloc_count();
        for _ in 0..10 {
            step(
                &mut comps, &mut grads, &mut wires, &mut ws, &mut acc, &mut r, &mut tracer,
                &mut step_no,
            );
        }
        let allocs = thread_alloc_count() - before;
        assert_eq!(
            allocs, 0,
            "steady-state receive/decode-reduce path (telemetry on) allocated {allocs} times"
        );
        assert!(tracer.recorded() >= 50 * 9, "spans actually recorded");
    }

    /// PR-3's zero-alloc acceptance gate, extended: the fused send path
    /// (compress → envelope → wire buffer) with membership checks enabled
    /// still performs ZERO heap allocations in steady state. The lib test
    /// binary runs under `testing::alloc::CountingAlloc`, so any
    /// allocation on this thread is caught.
    ///
    /// Telemetry is ON throughout (obs acceptance criterion): span +
    /// metric recording per step, still zero allocations.
    #[test]
    fn steady_state_fused_send_with_membership_checks_is_allocation_free() {
        use crate::compress::{CompressionConfig, NetSenseCompressor, Workspace};
        use crate::obs::{hot, Tracer};
        use crate::testing::alloc::thread_alloc_count;
        use crate::util::rng::Pcg64;

        let n = 20_000;
        let mut r = Pcg64::seeded(5);
        let mut w = vec![0f32; n];
        r.fill_normal_f32(&mut w, 0.0, 0.1);
        let mut g = vec![0f32; n];
        r.fill_normal_f32(&mut g, 0.0, 1.0);
        let m = Membership::new(0, 4);
        let ring = m.live_ring();
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut ws = Workspace::with_capacity(n);
        let mut wire: Vec<u8> = Vec::new();
        let mut tracer = Tracer::new(0, 128, std::time::Instant::now());
        let om = hot();
        let mut step_no = 0u32;
        let mut step = |c: &mut NetSenseCompressor,
                        ws: &mut Workspace,
                        wire: &mut Vec<u8>,
                        g: &mut [f32],
                        r: &mut Pcg64,
                        tracer: &mut Tracer,
                        step_no: &mut u32| {
            for x in g.iter_mut() {
                *x += 0.05 * r.normal() as f32;
            }
            // The membership checks the elastic send path performs every
            // step: epoch, liveness, ring neighbors — all allocation-free.
            assert!(m.is_live(ring.succ()) && m.is_live(ring.pred()));
            assert_eq!(m.n_live(), 4);
            let sp = tracer.start("compress", *step_no);
            let t_c = std::time::Instant::now();
            wire.clear();
            write_envelope(FrameKind::Data, m.epoch() as u32, 7, wire);
            c.compress_payload_into(g, &w, 0.1, ws, wire);
            om.compress_ns.observe(t_c.elapsed().as_nanos() as u64);
            tracer.end(sp);
            *step_no += 1;
        };
        for _ in 0..40 {
            step(&mut c, &mut ws, &mut wire, &mut g, &mut r, &mut tracer, &mut step_no);
        }
        let before = thread_alloc_count();
        for _ in 0..10 {
            step(&mut c, &mut ws, &mut wire, &mut g, &mut r, &mut tracer, &mut step_no);
        }
        let allocs = thread_alloc_count() - before;
        assert_eq!(
            allocs, 0,
            "membership-checked fused send path (telemetry on) allocated {allocs} times"
        );
        assert_eq!(tracer.recorded(), 50);
    }

    /// Byzantine duplication (ISSUE satellite): rank 1's two data frames
    /// of step 1 are re-delivered at step 2 with their step-1 envelopes.
    /// The step fencing must drop each exactly once — no recovery, no
    /// epoch bump, no corrupted blocks — and `RoundStats` must count them.
    #[test]
    fn duplicate_frames_are_fenced_exactly_once_and_counted() {
        let n = 3;
        let mut specs = vec![Vec::new(); n];
        specs[1] = vec![FaultSpec::DuplicateAtStep { step: 1 }];
        let outs = run_mesh_round(n, cfg_ms(2_000, 2_000), specs, 3);
        let mut fenced = 0u64;
        for (rank, out) in outs.iter().enumerate() {
            let rounds = out.as_ref().unwrap_or_else(|| panic!("rank {rank} died"));
            for r in rounds {
                assert_eq!(r.recoveries, 0, "rank {rank}: duplicates must be absorbed");
                assert!(!r.lost, "rank {rank}");
                assert_eq!(r.epoch, 0, "rank {rank}");
                assert_eq!(r.dropped_garbage, 0, "rank {rank}");
                // Payload integrity: every origin's block is the genuine
                // article, never a replayed copy misattributed.
                for (origin, b) in r.blocks.iter().enumerate() {
                    assert_eq!(
                        b.as_deref(),
                        Some(&vec![origin as u8; 10 + origin][..]),
                        "rank {rank}: origin {origin} corrupted"
                    );
                }
                fenced += r.dropped_stale;
            }
        }
        // Rank 1 forwards two data frames to its ring successor during
        // step 1 (its own block + the forwarded one); both replays land at
        // step 2 and are fenced there — exactly once each.
        assert_eq!(fenced, 2, "each duplicated frame must be dropped exactly once");
    }

    /// Byzantine reordering (ISSUE satellite): rank 1 withholds its step-1
    /// data past its own round budget and releases it behind its recovery
    /// probe. Every rank sees exactly one recovery, nobody is removed, and
    /// the released backlog is drained as stale — counted, never consumed.
    #[test]
    fn reordered_round_recovers_once_and_counts_released_backlog() {
        let n = 3;
        let mut specs = vec![Vec::new(); n];
        specs[1] = vec![FaultSpec::ReorderAtStep { step: 1 }];
        let outs = run_mesh_round(n, cfg_ms(150, 2_000), specs, 3);
        let mut fenced = 0u64;
        for (rank, out) in outs.iter().enumerate() {
            let rounds = out.as_ref().unwrap_or_else(|| panic!("rank {rank} died"));
            assert_eq!(rounds[1].recoveries, 1, "rank {rank}: exactly one recovery");
            assert!(rounds[1].lost, "rank {rank}");
            assert_eq!(rounds[1].epoch, 1, "rank {rank}");
            for r in rounds {
                let live = r.blocks.iter().filter(|b| b.is_some()).count();
                assert_eq!(live, n, "rank {rank}: a reorder must not kill anyone");
            }
            // Step 2 runs clean at the bumped epoch.
            assert_eq!(rounds[2].recoveries, 0, "rank {rank}");
            assert_eq!(rounds[2].epoch, 1, "rank {rank}");
            fenced += rounds[1].dropped_stale;
        }
        // The two withheld frames (both addressed to rank 1's ring
        // successor) are released behind the probe and drained as stale in
        // the successor's probe phase — exactly once each.
        assert_eq!(fenced, 2, "released backlog must be fenced exactly once");
    }

    /// Byzantine torn write, unparseable prefix (ISSUE satellite): rank 2
    /// dies mid-send at step 1 delivering 5 bytes — too short to be an
    /// envelope. Its ring successor must reject the fragment by parse
    /// (counted as garbage), then the group removes rank 2 like any kill.
    #[test]
    fn partial_write_garbage_prefix_is_rejected_and_rank_removed() {
        let n = 4;
        let mut specs = vec![Vec::new(); n];
        specs[2] = vec![FaultSpec::PartialSendAtStep { step: 1, keep_bytes: 5 }];
        let outs = run_mesh_round(n, cfg_ms(150, 600), specs, 3);
        assert!(outs[2].is_none(), "rank 2's solo zombie round must be discarded");
        let mut garbage = 0u64;
        for (rank, out) in outs.iter().enumerate() {
            if rank == 2 {
                continue;
            }
            let rounds = out.as_ref().unwrap_or_else(|| panic!("rank {rank} died"));
            assert_eq!(rounds.len(), 3);
            assert_eq!(rounds[1].recoveries, 1, "rank {rank}");
            assert_eq!(rounds[1].epoch, 1, "rank {rank}");
            assert!(rounds[1].blocks[2].is_none(), "rank {rank}: dead rank present");
            for (origin, b) in rounds[1].blocks.iter().enumerate() {
                if let Some(b) = b {
                    assert_eq!(
                        b,
                        &vec![origin as u8; 10 + origin],
                        "rank {rank}: torn bytes leaked into origin {origin}"
                    );
                }
            }
            garbage += rounds[1].dropped_garbage;
        }
        // Only rank 2's ring successor (rank 3) saw the 5-byte fragment.
        assert_eq!(garbage, 1, "the torn fragment must be dropped exactly once");
    }

    /// Byzantine torn write, *valid-envelope* prefix: rank 2's torn frame
    /// keeps its whole 9-byte envelope (current epoch + step) followed by
    /// a truncated body. The envelope layer cannot tell it from a healthy
    /// frame — it is accepted, forwarded, and the dead rank's ring
    /// predecessor (rank 1) completes the round *with* the torn block.
    /// This is where defense-in-depth hands over: the payload-validating
    /// reducer must reject the torn body as a named error that propagates
    /// out of `round_reduce` (the fused COO decode does exactly this in
    /// production), and the group then removes both rank 2 (dead) and
    /// rank 1 (failed loudly) in one recovery.
    #[test]
    fn partial_write_with_valid_envelope_is_caught_by_payload_validation() {
        let n = 4;
        let mut specs = vec![Vec::new(); n];
        // Rank 2's step-1 frame is 9 (envelope) + 12 (payload) = 21 bytes;
        // keep 15 → pristine envelope, 6-byte torn body.
        specs[2] = vec![FaultSpec::PartialSendAtStep { step: 1, keep_bytes: 15 }];
        let mesh = LoopbackTransport::mesh(n);
        let handles: Vec<_> = mesh
            .into_iter()
            .zip(specs)
            .map(|(t, spec)| {
                std::thread::spawn(move || {
                    let rank = t.rank();
                    let cfg = cfg_ms(150, 600);
                    let mut t = FaultInjector::new(Box::new(t), spec);
                    t.set_recv_timeout(cfg.recv_timeout());
                    let mut m = Membership::new(rank, n);
                    let mut ex = ElasticExchange::new(&m, cfg);
                    let mut completed = Vec::new();
                    for step in 0..3usize {
                        t.on_step(step);
                        if t.is_killed() {
                            return (rank, completed, None);
                        }
                        let payload = vec![rank as u8; 10 + rank];
                        // The payload-validating reducer every real
                        // deployment has: a body of the wrong shape is a
                        // named error, not data.
                        let r = ex.round_reduce(&mut t, &mut m, step as u32, &payload, |o, b| {
                            if b != vec![o as u8; 10 + o].as_slice() {
                                return Err(crate::util::error::anyhow!(
                                    "torn payload from rank {o}"
                                ));
                            }
                            Ok(())
                        });
                        match r {
                            Ok(_) if t.is_killed() => return (rank, completed, None),
                            Ok(stats) => completed.push(stats),
                            Err(_) if t.is_killed() => return (rank, completed, None),
                            Err(e) => return (rank, completed, Some(format!("{e}"))),
                        }
                    }
                    (rank, completed, None)
                })
            })
            .collect();
        let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Rank 2 died; its zombie solo round was discarded.
        assert!(outs[2].1.len() <= 1 && outs[2].2.is_none(), "rank 2 must just die");
        // Rank 1 — the dead rank's ring predecessor — completed the
        // poisoned round at the old epoch and must have rejected the torn
        // body loudly.
        let (_, ref r1_rounds, ref r1_err) = outs[1];
        assert_eq!(r1_rounds.len(), 1, "rank 1 completes step 0 only");
        let e = r1_err.as_ref().expect("rank 1 must fail loudly on the torn body");
        assert!(e.contains("torn payload from rank 2"), "{e}");
        // Ranks 0 and 3 recover past both casualties and finish all steps.
        for &rank in &[0usize, 3] {
            let (_, ref rounds, ref err) = outs[rank];
            assert!(err.is_none(), "rank {rank}: {err:?}");
            assert_eq!(rounds.len(), 3, "rank {rank} must finish");
            assert_eq!(rounds[1].recoveries, 1, "rank {rank}: one recovery");
            assert_eq!(rounds[1].epoch, 1, "rank {rank}");
            assert_eq!(rounds[1].n_blocks, 2, "rank {rank}: survivors are 0 and 3");
            assert_eq!(rounds[2].n_blocks, 2, "rank {rank}");
        }
    }

    #[test]
    fn two_rank_group_degrades_to_solo() {
        let n = 2;
        let mut specs = vec![Vec::new(); n];
        specs[1] = vec![FaultSpec::KillAtStep { step: 1 }];
        let outs = run_mesh_round(n, cfg_ms(100, 400), specs, 3);
        let rounds = outs[0].as_ref().unwrap();
        assert_eq!(rounds[1].epoch, 1);
        assert!(rounds[1].blocks[1].is_none());
        // Solo ring: the round is the identity, instantly.
        assert_eq!(rounds[2].recoveries, 0);
        assert_eq!(
            rounds[2].blocks[0].as_deref(),
            Some(&vec![0u8; 10][..])
        );
    }
}
