//! Fault-tolerant elastic membership: survive stragglers, dead ranks,
//! and flapping links end-to-end.
//!
//! The worst "network condition" a distributed trainer meets in
//! production is a rank that stops answering — without this module a
//! single dead worker deadlocks the ring in
//! [`crate::transport::collective`] forever, and none of the paper's
//! adaptive machinery ever gets a chance to react. This subsystem makes
//! the group *elastic*:
//!
//! - [`membership`] — an epoch-numbered live-rank view per worker, with
//!   the suspect → dead state machine ([`Membership`], [`RankState`]) and
//!   the collective ring over survivors ([`LiveRing`]).
//! - [`injector`] — deterministic fault injection at the transport seam
//!   ([`FaultInjector`], [`FaultSpec`]): kill-at-step, stall-for-duration,
//!   flapping link, all keyed by training step so chaos runs replay
//!   exactly.
//! - [`collective`] — the degraded collective ([`ElasticExchange`]): an
//!   epoch-tagged ring all-gather that reports the suspect on a deadline,
//!   agrees on a new epoch through an all-to-all probe round, rebuilds the
//!   ring over survivors, and replays the interrupted round. The hot path
//!   ([`ElasticExchange::round_reduce`]) hands each completed round's
//!   payloads to a reducer as borrowed, envelope-stripped slices over
//!   reusable buffers — the receive side of a healthy round allocates
//!   nothing in steady state.
//! - [`checkpoint`] — compressor-state snapshot/restore
//!   ([`Checkpoint`]): error-feedback residuals (and the selection caches
//!   that make compression bit-deterministic) serialize so a rejoining
//!   rank resumes without corrupting convergence.
//!
//! The same failure schedule drives live runs
//! ([`crate::experiments::live`] wires [`FaultInjector`] into every
//! worker) and the simulator ([`sim_trajectory`] replays the schedule
//! against [`crate::netsim`]): both produce the same
//! [`SyncTrajectory`] — the chaos-determinism contract the end-to-end
//! test asserts.
//!
//! Failure-model assumptions (documented, not hidden): ranks are
//! fail-stop (a dead rank stays dead; rejoin is a new process resuming
//! from a [`Checkpoint`]), and recovery latency is bounded by the probe
//! deadline — a rank slower than that is indistinguishable from a dead
//! one and is removed (the lease assumption every practical membership
//! service makes).

pub mod checkpoint;
pub mod collective;
pub mod injector;
pub mod membership;

pub use checkpoint::Checkpoint;
pub use collective::{
    parse_envelope, write_envelope, ElasticExchange, ElasticRound, FrameKind, RoundStats,
    ENVELOPE_OVERHEAD,
};
pub use injector::{FaultInjector, FaultSpec};
pub use membership::{LiveRing, Membership, RankState};

use crate::netsim::schedule::mbps;
use crate::netsim::topology::StarTopology;
use crate::netsim::{NetSim, SimTime};
use std::time::Duration;

/// Deadlines of the failure detector (the `[fault]` config table).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultConfig {
    /// Per-recv deadline during a collective round, ms. A peer silent for
    /// longer is suspected and the round aborts into recovery.
    pub recv_timeout_ms: u64,
    /// Per-peer deadline of the recovery probe round, ms. A suspect that
    /// fails to answer a probe within it is declared dead.
    pub probe_timeout_ms: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            recv_timeout_ms: 10_000,
            probe_timeout_ms: 10_000,
        }
    }
}

impl FaultConfig {
    pub fn recv_timeout(&self) -> Duration {
        Duration::from_millis(self.recv_timeout_ms)
    }

    pub fn probe_timeout(&self) -> Duration {
        Duration::from_millis(self.probe_timeout_ms)
    }
}

/// A whole-group failure schedule, keyed by `(rank, step)` — the single
/// source both the live [`FaultInjector`]s and the netsim mirror
/// ([`sim_trajectory`]) execute.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    /// `(rank, step)`: the rank dies at the start of `step`.
    pub kills: Vec<(usize, usize)>,
    /// `(rank, step, stall_ms)`: the rank stalls for `stall_ms` at `step`.
    pub stalls: Vec<(usize, usize, u64)>,
    /// `(rank, step, down_ms)`: the rank's link flaps down for `down_ms`
    /// starting at `step`.
    pub flaps: Vec<(usize, usize, u64)>,
    /// `(rank, step)`: Byzantine duplication — every data frame the rank
    /// sends during `step` is re-delivered one step later (stale-envelope
    /// replay the step fencing must absorb without a recovery).
    pub duplicates: Vec<(usize, usize)>,
    /// `(rank, step)`: Byzantine reordering — the rank's data frames are
    /// withheld across the round boundary and released behind its next
    /// probe (peers see one recovery, nobody removed).
    pub reorders: Vec<(usize, usize)>,
    /// `(rank, step, keep_bytes)`: Byzantine torn write — the rank dies
    /// mid-send at `step`, delivering only the frame's first `keep_bytes`
    /// bytes (a kill whose last frame is garbage on the wire).
    pub partial_kills: Vec<(usize, usize, usize)>,
}

impl FaultSchedule {
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.stalls.is_empty()
            && self.flaps.is_empty()
            && self.duplicates.is_empty()
            && self.reorders.is_empty()
            && self.partial_kills.is_empty()
    }

    /// The fault specs `rank`'s endpoint executes.
    pub fn specs_for(&self, rank: usize) -> Vec<FaultSpec> {
        let mut specs = Vec::new();
        for &(r, step) in &self.kills {
            if r == rank {
                specs.push(FaultSpec::KillAtStep { step });
            }
        }
        for &(r, step, stall_ms) in &self.stalls {
            if r == rank {
                specs.push(FaultSpec::StallAtStep { step, stall_ms });
            }
        }
        for &(r, step, down_ms) in &self.flaps {
            if r == rank {
                specs.push(FaultSpec::FlapAtStep { step, down_ms });
            }
        }
        for &(r, step) in &self.duplicates {
            if r == rank {
                specs.push(FaultSpec::DuplicateAtStep { step });
            }
        }
        for &(r, step) in &self.reorders {
            if r == rank {
                specs.push(FaultSpec::ReorderAtStep { step });
            }
        }
        for &(r, step, keep_bytes) in &self.partial_kills {
            if r == rank {
                specs.push(FaultSpec::PartialSendAtStep { step, keep_bytes });
            }
        }
        specs
    }

    /// The step `rank` is scheduled to die at, if any — a partial kill is
    /// a kill (the rank is gone after its torn send), so rank-0 validation
    /// and the mirror's death accounting cover both.
    pub fn kill_step(&self, rank: usize) -> Option<usize> {
        self.kills
            .iter()
            .map(|&(r, step)| (r, step))
            .chain(self.partial_kills.iter().map(|&(r, step, _)| (r, step)))
            .find(|&(r, _)| r == rank)
            .map(|(_, step)| step)
    }

    /// Largest rank referenced (for config validation).
    pub fn max_rank(&self) -> Option<usize> {
        self.kills
            .iter()
            .map(|&(r, _)| r)
            .chain(self.stalls.iter().map(|&(r, _, _)| r))
            .chain(self.flaps.iter().map(|&(r, _, _)| r))
            .chain(self.duplicates.iter().map(|&(r, _)| r))
            .chain(self.reorders.iter().map(|&(r, _)| r))
            .chain(self.partial_kills.iter().map(|&(r, _, _)| r))
            .max()
    }
}

/// One stretch of training at a fixed membership view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrajectorySegment {
    pub epoch: u64,
    pub group_size: usize,
    /// Synchronization rounds completed in this segment.
    pub syncs: u64,
}

/// The epoch/live-set trajectory of a run: what the chaos-determinism
/// contract compares between a live run and its netsim mirror.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SyncTrajectory {
    pub segments: Vec<TrajectorySegment>,
    /// Virtual time the simulator spent moving the segments' bytes
    /// (0 for trajectories folded out of a live trace).
    pub vtime_s: f64,
}

impl SyncTrajectory {
    /// Append one sync at `(epoch, group_size)`, folding into the last
    /// segment when the view is unchanged.
    pub fn record(&mut self, epoch: u64, group_size: usize) {
        match self.segments.last_mut() {
            Some(seg) if seg.epoch == epoch && seg.group_size == group_size => seg.syncs += 1,
            _ => self.segments.push(TrajectorySegment {
                epoch,
                group_size,
                syncs: 1,
            }),
        }
    }

    pub fn total_syncs(&self) -> u64 {
        self.segments.iter().map(|s| s.syncs).sum()
    }
}

/// Replay a [`FaultSchedule`] against the simulator: the same membership
/// state machine the live workers run, with each segment's synchronization
/// rounds moved over a [`NetSim`] star sized to the surviving group. The
/// returned trajectory must equal the live run's
/// ([`crate::experiments::live::LiveReport::trajectory`]) — failure
/// handling is schedule-deterministic; wall clock only shifts *when*
/// recovery happens, never *what* it decides.
///
/// The events mirror the live semantics: a kill — torn-write partial
/// kills included — always triggers a recovery (epoch +1, rank removed);
/// a stall or flap triggers one only when it exceeds
/// `cfg.recv_timeout_ms` (epoch +1, nobody removed — the probe round
/// finds the straggler alive). Of the Byzantine schedules, a reorder
/// always disrupts (the reordering rank blocks past its own round budget,
/// so the group recovers and finds everyone alive), while a duplicate is
/// *absorbed*: the replayed frames arrive one step stale and the envelope
/// fencing drops them without a recovery — the mirror counts nothing.
pub fn sim_trajectory(
    world: usize,
    steps: usize,
    schedule: &FaultSchedule,
    cfg: &FaultConfig,
    payload_bytes: u64,
) -> SyncTrajectory {
    let mut m = Membership::new(0, world);
    let mut traj = SyncTrajectory::default();
    let mut vtime_acc = 0.0f64;
    let mut sim = NetSim::quiet(StarTopology::constant(
        world,
        mbps(1_000.0),
        SimTime::from_millis(1),
    ));
    for step in 0..steps {
        // Faults only fire on ranks still alive — a stall or flap
        // scheduled on a rank after its own kill never reaches the wire
        // in the live run either (the injector's endpoint is dead).
        let dead: Vec<usize> = schedule
            .kills
            .iter()
            .map(|&(r, s)| (r, s))
            .chain(schedule.partial_kills.iter().map(|&(r, s, _)| (r, s)))
            .filter(|&(r, s)| s == step && m.is_live(r))
            .map(|(r, _)| r)
            .collect();
        let disrupted = schedule
            .stalls
            .iter()
            .any(|&(r, s, ms)| s == step && ms > cfg.recv_timeout_ms && m.is_live(r))
            || schedule
                .flaps
                .iter()
                .any(|&(r, s, ms)| s == step && ms > cfg.recv_timeout_ms && m.is_live(r))
            // A reorder blocks its own rank past the round budget, so it
            // always costs one recovery; duplicates are absorbed by the
            // step fencing and never appear here.
            || schedule
                .reorders
                .iter()
                .any(|&(r, s)| s == step && m.is_live(r));
        if !dead.is_empty() || disrupted {
            m.begin_epoch(&dead);
            // The ring rebuilds over survivors: a fresh star topology per
            // membership change (virtual time accumulates across them).
            if !dead.is_empty() {
                vtime_acc += sim.now().as_secs_f64();
                sim = NetSim::quiet(StarTopology::constant(
                    m.n_live().max(1),
                    mbps(1_000.0),
                    SimTime::from_millis(1),
                ));
            }
        }
        if m.n_live() > 1 {
            let payloads = vec![payload_bytes; m.n_live()];
            crate::collectives::patterns::ring_allgather(&mut sim, &payloads);
        }
        traj.record(m.epoch(), m.n_live());
    }
    traj.vtime_s = vtime_acc + sim.now().as_secs_f64();
    traj
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trajectory_folds_consecutive_views() {
        let mut t = SyncTrajectory::default();
        for _ in 0..5 {
            t.record(0, 4);
        }
        for _ in 0..3 {
            t.record(1, 3);
        }
        assert_eq!(
            t.segments,
            vec![
                TrajectorySegment { epoch: 0, group_size: 4, syncs: 5 },
                TrajectorySegment { epoch: 1, group_size: 3, syncs: 3 },
            ]
        );
        assert_eq!(t.total_syncs(), 8);
    }

    #[test]
    fn sim_trajectory_kill_splits_segments() {
        let schedule = FaultSchedule {
            kills: vec![(2, 6)],
            ..Default::default()
        };
        let t = sim_trajectory(4, 14, &schedule, &FaultConfig::default(), 10_000);
        assert_eq!(
            t.segments,
            vec![
                TrajectorySegment { epoch: 0, group_size: 4, syncs: 6 },
                TrajectorySegment { epoch: 1, group_size: 3, syncs: 8 },
            ]
        );
        assert!(t.vtime_s > 0.0, "netsim must have moved bytes");
    }

    #[test]
    fn sim_trajectory_flap_bumps_epoch_without_deaths() {
        let cfg = FaultConfig {
            recv_timeout_ms: 100,
            probe_timeout_ms: 500,
        };
        let schedule = FaultSchedule {
            flaps: vec![(1, 3, 300)],
            stalls: vec![(1, 5, 20)], // sub-deadline: absorbed, no bump
            ..Default::default()
        };
        let t = sim_trajectory(3, 8, &schedule, &cfg, 1_000);
        assert_eq!(
            t.segments,
            vec![
                TrajectorySegment { epoch: 0, group_size: 3, syncs: 3 },
                TrajectorySegment { epoch: 1, group_size: 3, syncs: 5 },
            ]
        );
    }

    #[test]
    fn sim_trajectory_ignores_faults_on_dead_ranks() {
        // A flap scheduled after the same rank's kill never reaches the
        // wire in a live run (the endpoint is dead) — the mirror must
        // not count it either.
        let cfg = FaultConfig {
            recv_timeout_ms: 100,
            probe_timeout_ms: 500,
        };
        let schedule = FaultSchedule {
            kills: vec![(2, 3)],
            flaps: vec![(2, 6, 400)],
            ..Default::default()
        };
        let t = sim_trajectory(3, 8, &schedule, &cfg, 1_000);
        assert_eq!(
            t.segments,
            vec![
                TrajectorySegment { epoch: 0, group_size: 3, syncs: 3 },
                TrajectorySegment { epoch: 1, group_size: 2, syncs: 5 },
            ]
        );
    }

    #[test]
    fn sim_trajectory_no_faults_is_one_segment() {
        let t = sim_trajectory(4, 10, &FaultSchedule::default(), &FaultConfig::default(), 1_000);
        assert_eq!(
            t.segments,
            vec![TrajectorySegment { epoch: 0, group_size: 4, syncs: 10 }]
        );
    }

    #[test]
    fn schedule_helpers() {
        let s = FaultSchedule {
            kills: vec![(3, 9)],
            stalls: vec![(1, 2, 40)],
            ..Default::default()
        };
        assert!(!s.is_empty());
        assert!(FaultSchedule::default().is_empty());
        assert_eq!(s.max_rank(), Some(3));
        assert_eq!(s.kill_step(3), Some(9));
        assert_eq!(s.kill_step(0), None);
        // The Byzantine fields count toward emptiness and rank bounds, and
        // a partial kill reports as a kill.
        let b = FaultSchedule {
            duplicates: vec![(1, 2)],
            reorders: vec![(2, 4)],
            partial_kills: vec![(5, 7, 3)],
            ..Default::default()
        };
        assert!(!b.is_empty());
        assert_eq!(b.max_rank(), Some(5));
        assert_eq!(b.kill_step(5), Some(7));
        assert_eq!(b.kill_step(1), None);
    }

    /// Duplicated frames are absorbed by the step fencing: the mirror
    /// must show a single unbroken segment, same as no fault at all.
    #[test]
    fn sim_trajectory_duplicate_is_absorbed() {
        let schedule = FaultSchedule {
            duplicates: vec![(1, 3)],
            ..Default::default()
        };
        let t = sim_trajectory(3, 8, &schedule, &FaultConfig::default(), 1_000);
        assert_eq!(
            t.segments,
            vec![TrajectorySegment { epoch: 0, group_size: 3, syncs: 8 }]
        );
    }

    /// A reorder costs one recovery — epoch bump, nobody removed — like
    /// an over-deadline flap.
    #[test]
    fn sim_trajectory_reorder_bumps_epoch_without_deaths() {
        let schedule = FaultSchedule {
            reorders: vec![(2, 4)],
            ..Default::default()
        };
        let t = sim_trajectory(3, 9, &schedule, &FaultConfig::default(), 1_000);
        assert_eq!(
            t.segments,
            vec![
                TrajectorySegment { epoch: 0, group_size: 3, syncs: 4 },
                TrajectorySegment { epoch: 1, group_size: 3, syncs: 5 },
            ]
        );
    }

    /// A partial kill is a kill on the trajectory: epoch bump and the
    /// rank removed (the torn bytes themselves are a parse-level concern
    /// the collective tests cover).
    #[test]
    fn sim_trajectory_partial_kill_removes_the_rank() {
        let schedule = FaultSchedule {
            partial_kills: vec![(2, 5, 5)],
            ..Default::default()
        };
        let t = sim_trajectory(4, 12, &schedule, &FaultConfig::default(), 1_000);
        assert_eq!(
            t.segments,
            vec![
                TrajectorySegment { epoch: 0, group_size: 4, syncs: 5 },
                TrajectorySegment { epoch: 1, group_size: 3, syncs: 7 },
            ]
        );
    }
}
