//! Epoch-numbered membership: which ranks are alive, and the
//! suspect → dead state machine every survivor advances identically.
//!
//! The tracker is deliberately *local* — each rank holds its own
//! [`Membership`] and updates it from its own observations (recv
//! timeouts, probe results, heartbeats piggybacked on collective data
//! frames). Agreement comes from the recovery protocol in
//! [`super::collective`]: every survivor runs the same all-to-all probe
//! round after an abort, so every survivor removes the same dead set and
//! lands on the same epoch. Given the same failure schedule, the
//! epoch/live-set trajectory is therefore bit-deterministic across ranks
//! (tested here and end-to-end in [`crate::experiments::live`]).
//!
//! ```
//! use netsenseml::fault::{Membership, RankState};
//!
//! let mut m = Membership::new(1, 4);
//! assert_eq!(m.epoch(), 0);
//! assert_eq!(m.n_live(), 4);
//! m.suspect(3);
//! assert_eq!(m.state(3), RankState::Suspect { strikes: 1 });
//! m.heartbeat(3); // a frame arrived after all — suspicion cleared
//! assert_eq!(m.state(3), RankState::Alive);
//! m.begin_epoch(&[3]); // probe round confirmed rank 3 dead
//! assert_eq!(m.epoch(), 1);
//! assert_eq!(m.live_ranks(), vec![0, 1, 2]);
//! let ring = m.live_ring();
//! assert_eq!((ring.succ(), ring.pred()), (2, 0));
//! ```

/// Liveness state of one rank, as seen by the local tracker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankState {
    /// Answering normally.
    Alive,
    /// Missed `strikes` consecutive deadlines; cleared by any heartbeat,
    /// promoted to [`RankState::Dead`] only by a failed probe round.
    Suspect { strikes: u32 },
    /// Confirmed unreachable. Absorbing: this PR's membership never
    /// resurrects a dead rank in-place — a rejoin is a new run resuming
    /// from a [`super::Checkpoint`].
    Dead,
}

impl RankState {
    pub fn is_live(&self) -> bool {
        !matches!(self, RankState::Dead)
    }
}

/// One rank's epoch-numbered view of the worker group.
#[derive(Clone, Debug)]
pub struct Membership {
    self_rank: usize,
    epoch: u64,
    states: Vec<RankState>,
}

impl Membership {
    /// Epoch 0: everyone alive.
    pub fn new(self_rank: usize, world: usize) -> Membership {
        assert!(world >= 1, "empty group");
        assert!(self_rank < world, "self rank {self_rank} out of range");
        Membership {
            self_rank,
            epoch: 0,
            states: vec![RankState::Alive; world],
        }
    }

    /// Current membership epoch. Bumps by exactly one per recovery event
    /// (even a recovery that killed nobody — a flapping link — bumps, so
    /// replayed rounds are never confused with the aborted round's stale
    /// frames).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Group size at launch (dead ranks included).
    pub fn world(&self) -> usize {
        self.states.len()
    }

    pub fn self_rank(&self) -> usize {
        self.self_rank
    }

    pub fn state(&self, rank: usize) -> RankState {
        self.states[rank]
    }

    /// Alive or suspect (suspects still get probes and frames).
    pub fn is_live(&self, rank: usize) -> bool {
        self.states[rank].is_live()
    }

    pub fn n_live(&self) -> usize {
        self.states.iter().filter(|s| s.is_live()).count()
    }

    /// Live ranks in ascending order (self included).
    pub fn live_ranks(&self) -> Vec<usize> {
        (0..self.world()).filter(|&r| self.is_live(r)).collect()
    }

    /// A frame from `rank` arrived — collective data frames double as
    /// heartbeats. Clears suspicion; a dead rank stays dead.
    pub fn heartbeat(&mut self, rank: usize) {
        if matches!(self.states[rank], RankState::Suspect { .. }) {
            self.states[rank] = RankState::Alive;
        }
    }

    /// `rank` missed a deadline (recv timeout / send error). Returns the
    /// new state. Never kills — death is decided by the probe round.
    pub fn suspect(&mut self, rank: usize) -> RankState {
        self.states[rank] = match self.states[rank] {
            RankState::Alive => RankState::Suspect { strikes: 1 },
            RankState::Suspect { strikes } => RankState::Suspect {
                strikes: strikes.saturating_add(1),
            },
            RankState::Dead => RankState::Dead,
        };
        self.states[rank]
    }

    /// Commit a recovery: mark `dead` ranks dead, clear every surviving
    /// suspicion, and bump the epoch. Returns the new epoch. The caller
    /// (the probe round) guarantees every survivor passes the same set.
    pub fn begin_epoch(&mut self, dead: &[usize]) -> u64 {
        for &r in dead {
            assert!(r != self.self_rank, "cannot declare self dead");
            self.states[r] = RankState::Dead;
        }
        for s in self.states.iter_mut() {
            if matches!(s, RankState::Suspect { .. }) {
                *s = RankState::Alive;
            }
        }
        self.epoch += 1;
        self.epoch
    }

    /// The ring over the current live set (self must be live).
    pub fn live_ring(&self) -> LiveRing {
        let ranks = self.live_ranks();
        let pos = ranks
            .iter()
            .position(|&r| r == self.self_rank)
            .expect("self rank must be live to build a ring");
        LiveRing { ranks, pos }
    }
}

/// The collective ring over the live ranks of one epoch: positions are
/// indices into the sorted live set, `pos` is where `self` sits. Rebuilt
/// only on epoch change, so per-step membership checks stay
/// allocation-free.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LiveRing {
    /// Live ranks, ascending.
    pub ranks: Vec<usize>,
    /// Index of the local rank in `ranks`.
    pub pos: usize,
}

impl LiveRing {
    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }

    /// Alone in the group — collectives degenerate to the identity.
    pub fn is_solo(&self) -> bool {
        self.ranks.len() == 1
    }

    /// Absolute rank of the ring successor.
    pub fn succ(&self) -> usize {
        self.ranks[(self.pos + 1) % self.ranks.len()]
    }

    /// Absolute rank of the ring predecessor.
    pub fn pred(&self) -> usize {
        self.ranks[(self.pos + self.ranks.len() - 1) % self.ranks.len()]
    }

    /// Absolute rank at ring position `p`.
    pub fn rank_at(&self, p: usize) -> usize {
        self.ranks[p % self.ranks.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_all_alive_at_epoch_zero() {
        let m = Membership::new(0, 4);
        assert_eq!(m.epoch(), 0);
        assert_eq!(m.n_live(), 4);
        assert_eq!(m.live_ranks(), vec![0, 1, 2, 3]);
        assert!(m.is_live(3));
    }

    #[test]
    fn suspect_accumulates_strikes_and_heartbeat_clears() {
        let mut m = Membership::new(0, 3);
        assert_eq!(m.suspect(2), RankState::Suspect { strikes: 1 });
        assert_eq!(m.suspect(2), RankState::Suspect { strikes: 2 });
        assert!(m.is_live(2), "suspects still count as live");
        m.heartbeat(2);
        assert_eq!(m.state(2), RankState::Alive);
    }

    #[test]
    fn begin_epoch_kills_clears_suspicion_and_bumps() {
        let mut m = Membership::new(0, 4);
        m.suspect(1);
        m.suspect(3);
        let e = m.begin_epoch(&[3]);
        assert_eq!(e, 1);
        assert_eq!(m.state(3), RankState::Dead);
        assert_eq!(m.state(1), RankState::Alive, "survivor suspicion cleared");
        assert_eq!(m.live_ranks(), vec![0, 1, 2]);
        assert_eq!(m.n_live(), 3);
    }

    #[test]
    fn empty_recovery_still_bumps_epoch() {
        // A flapping link aborts a round without killing anyone; the epoch
        // must still advance so the replay's frames outrank stale ones.
        let mut m = Membership::new(0, 2);
        m.suspect(1);
        assert_eq!(m.begin_epoch(&[]), 1);
        assert_eq!(m.n_live(), 2);
        assert_eq!(m.state(1), RankState::Alive);
    }

    #[test]
    fn dead_is_absorbing() {
        let mut m = Membership::new(0, 3);
        m.begin_epoch(&[2]);
        m.heartbeat(2);
        assert_eq!(m.state(2), RankState::Dead);
        assert_eq!(m.suspect(2), RankState::Dead);
    }

    #[test]
    fn ring_rebuilds_over_survivors() {
        let mut m = Membership::new(2, 4);
        let ring = m.live_ring();
        assert_eq!(ring.ranks, vec![0, 1, 2, 3]);
        assert_eq!((ring.pos, ring.succ(), ring.pred()), (2, 3, 1));
        m.begin_epoch(&[3]);
        let ring = m.live_ring();
        assert_eq!(ring.ranks, vec![0, 1, 2]);
        assert_eq!((ring.succ(), ring.pred()), (0, 1));
        m.begin_epoch(&[0, 1]);
        let ring = m.live_ring();
        assert!(ring.is_solo());
        assert_eq!((ring.succ(), ring.pred()), (2, 2));
    }

    #[test]
    fn identical_observations_produce_identical_views() {
        // The agreement property recovery relies on: two ranks applying
        // the same dead sets in the same order converge to the same view.
        let mut a = Membership::new(0, 5);
        let mut b = Membership::new(3, 5);
        for dead in [vec![2], vec![], vec![4, 1]] {
            a.begin_epoch(&dead);
            b.begin_epoch(&dead);
        }
        assert_eq!(a.epoch(), b.epoch());
        assert_eq!(a.live_ranks(), b.live_ranks());
        assert_eq!(a.epoch(), 3);
        assert_eq!(a.live_ranks(), vec![0, 3]);
    }

    #[test]
    #[should_panic(expected = "cannot declare self dead")]
    fn self_death_rejected() {
        let mut m = Membership::new(1, 2);
        m.begin_epoch(&[1]);
    }
}
