//! Deterministic fault injection at the transport seam: a
//! [`Transport`] wrapper that kills, stalls, or flaps this endpoint on a
//! step-indexed schedule, so live runs and netsim runs can exercise the
//! *same* failure scenario ([`super::sim_trajectory`] is the simulator
//! mirror of the same schedule).
//!
//! Faults are keyed by training step, not wall clock — the worker loop
//! reports its step via [`FaultInjector::on_step`], which is what makes a
//! chaos run replayable: the same schedule produces the same epoch/live
//! trajectory every time (wall-clock only shifts *when* the recovery
//! happens, never *what* it decides).

use super::FaultSchedule;
use crate::obs;
use crate::transport::{Transport, TransferObs};
use crate::util::error::{anyhow, Result};
use std::time::{Duration, Instant};

/// One fault on one rank's endpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSpec {
    /// From the start of `step`, the endpoint is dead: every send/recv
    /// errors and the inner transport is shut down (peers observe a
    /// disconnect or a recv timeout).
    KillAtStep { step: usize },
    /// A straggler: the first send of `step` is delayed by `stall_ms`
    /// (local compute hiccup — GC pause, preemption). Below the group's
    /// recv timeout it is absorbed as a slow round; above it, peers run a
    /// recovery that finds everyone alive.
    StallAtStep { step: usize, stall_ms: u64 },
    /// A flapping link: from the first send at/after `step`, the link is
    /// down for `down_ms` of wall clock — sends block until the link heals
    /// (outage buffering), so peers time out, recover, and the replayed
    /// round finds the rank alive again.
    FlapAtStep { step: usize, down_ms: u64 },
    /// Byzantine duplication: every *data* frame this endpoint sends
    /// during `step` is recorded and re-delivered verbatim at the start of
    /// the next step — a retransmitting NIC or a middlebox replaying a
    /// window. The copies carry the previous step's envelope, so the
    /// elastic layer's step fencing must drop each exactly once
    /// ([`RoundStats::dropped_stale`](super::RoundStats)).
    DuplicateAtStep { step: usize },
    /// Byzantine reordering: from `step`, outgoing data frames are
    /// withheld (and the first withheld send blocks past this endpoint's
    /// own round budget, forcing it to abort the round like a real
    /// head-of-line blockage would) until the endpoint's first probe send
    /// releases them — data drains before the probe, per-peer FIFO intact,
    /// but a full round boundary late.
    ReorderAtStep { step: usize },
    /// Byzantine torn write: the first send of `step` delivers only the
    /// leading `keep_bytes` of the frame, then the endpoint dies exactly
    /// as [`FaultSpec::KillAtStep`] — a process crash mid-`write(2)`.
    /// Peers must reject the torn frame by parse, never by trust.
    PartialSendAtStep { step: usize, keep_bytes: usize },
}

impl FaultSpec {
    fn step(&self) -> usize {
        match self {
            FaultSpec::KillAtStep { step }
            | FaultSpec::StallAtStep { step, .. }
            | FaultSpec::FlapAtStep { step, .. }
            | FaultSpec::DuplicateAtStep { step }
            | FaultSpec::ReorderAtStep { step }
            | FaultSpec::PartialSendAtStep { step, .. } => *step,
        }
    }
}

/// A [`Transport`] wrapper executing this rank's slice of a
/// [`FaultSchedule`]. An empty spec list is a pass-through, so the worker
/// loop always runs with the injector (and its membership checks) on.
pub struct FaultInjector {
    inner: Box<dyn Transport>,
    specs: Vec<FaultSpec>,
    killed: bool,
    /// Pending one-shot stall (ms), armed by [`Self::on_step`], consumed
    /// by the next send.
    stall_pending: Option<u64>,
    /// The flap outage end, armed by [`Self::on_step`]; sends before it
    /// block until it passes.
    flap_until: Option<Instant>,
    /// Recording data frames for Byzantine duplication this step.
    dup_recording: bool,
    /// Data frames recorded this step, re-delivered at the next
    /// [`Self::on_step`] (where their envelope is one step stale).
    dup_buffer: Vec<(usize, Vec<u8>)>,
    /// Withholding data frames for Byzantine reordering this step.
    reorder_armed: bool,
    /// The reorder head-of-line block already happened (only the first
    /// withheld send stalls).
    reorder_stalled: bool,
    /// Withheld data frames, released by the first probe send (or the next
    /// [`Self::on_step`] as one-step-stale frames if no probe came).
    reorder_buffer: Vec<(usize, Vec<u8>)>,
    /// Pending torn write: deliver this many bytes of the next send, then
    /// die.
    partial_pending: Option<usize>,
    /// Last deadline forwarded through [`Transport::set_recv_timeout`] —
    /// the reorder stall sleeps just past it so this endpoint's own round
    /// budget expires, mirroring real head-of-line blocking.
    recv_timeout: Duration,
}

impl FaultInjector {
    pub fn new(inner: Box<dyn Transport>, specs: Vec<FaultSpec>) -> FaultInjector {
        FaultInjector {
            inner,
            specs,
            killed: false,
            stall_pending: None,
            flap_until: None,
            dup_recording: false,
            dup_buffer: Vec::new(),
            reorder_armed: false,
            reorder_stalled: false,
            reorder_buffer: Vec::new(),
            partial_pending: None,
            recv_timeout: Duration::from_secs(10),
        }
    }

    /// Wrap with this rank's slice of a whole-group schedule.
    pub fn from_schedule(inner: Box<dyn Transport>, schedule: &FaultSchedule) -> FaultInjector {
        let rank = inner.rank();
        FaultInjector::new(inner, schedule.specs_for(rank))
    }

    /// The worker loop is entering training step `step` — arm any faults
    /// scheduled for it.
    pub fn on_step(&mut self, step: usize) {
        // First, deliver last step's Byzantine leftovers: duplicated
        // recordings and any still-withheld reorder frames go out now,
        // carrying the *previous* step's envelope — exactly the stale
        // frames the elastic layer's step fencing must absorb. Delivery
        // failures are part of the chaos (the peer may be gone).
        if !self.dup_buffer.is_empty() {
            obs::hot()
                .faults_duplicate_total
                .add(self.dup_buffer.len() as u64);
        }
        let stale: Vec<(usize, Vec<u8>)> = self
            .dup_buffer
            .drain(..)
            .chain(self.reorder_buffer.drain(..))
            .collect();
        for (to, frame) in stale {
            let _ = self.inner.send(to, &frame);
        }
        self.dup_recording = false;
        self.reorder_armed = false;
        self.reorder_stalled = false;

        let (mut kill, mut stall, mut flap) = (false, None, None);
        for spec in &self.specs {
            if spec.step() != step {
                continue;
            }
            match *spec {
                FaultSpec::KillAtStep { .. } => kill = true,
                FaultSpec::StallAtStep { stall_ms, .. } => stall = Some(stall_ms),
                FaultSpec::FlapAtStep { down_ms, .. } => flap = Some(down_ms),
                FaultSpec::DuplicateAtStep { .. } => self.dup_recording = true,
                FaultSpec::ReorderAtStep { .. } => self.reorder_armed = true,
                FaultSpec::PartialSendAtStep { keep_bytes, .. } => {
                    self.partial_pending = Some(keep_bytes)
                }
            }
        }
        if kill {
            self.killed = true;
            obs::hot().faults_kill_total.inc();
            let _ = self.inner.shutdown();
        }
        if let Some(ms) = stall {
            self.stall_pending = Some(ms);
        }
        if let Some(ms) = flap {
            self.flap_until = Some(Instant::now() + Duration::from_millis(ms));
        }
    }

    /// Did a `KillAtStep` fire? The worker uses this to distinguish its
    /// own planned death (return a partial trace) from a real failure
    /// (propagate the error).
    pub fn is_killed(&self) -> bool {
        self.killed
    }

    fn dead_err(&self) -> crate::util::error::Error {
        anyhow!("injected-kill: rank {} is dead", self.inner.rank())
    }
}

impl Transport for FaultInjector {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn group_size(&self) -> usize {
        self.inner.group_size()
    }

    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        if self.killed {
            return Err(self.dead_err());
        }
        if let Some(ms) = self.stall_pending.take() {
            obs::hot().faults_stall_total.inc();
            // Injected delays are event-loop timer deadlines, same as the
            // shaping layer — no wrapper thread burns a blocking sleep.
            crate::util::poller::sleep_until(Instant::now() + Duration::from_millis(ms));
        }
        if let Some(until) = self.flap_until {
            obs::hot().faults_flap_total.inc();
            crate::util::poller::sleep_until(until);
            self.flap_until = None;
        }
        // Torn write: deliver a prefix of the frame, then die mid-call —
        // the peer holds bytes that parse to nothing (or to a valid
        // envelope with a torn body) and must reject them by parse.
        if let Some(keep) = self.partial_pending.take() {
            obs::hot().faults_partial_total.inc();
            let _ = self.inner.send(to, &payload[..keep.min(payload.len())]);
            self.killed = true;
            let _ = self.inner.shutdown();
            return Err(self.dead_err());
        }
        // Reordering: withhold data frames until this endpoint's first
        // probe send (which a round recovery always begins with). The
        // first withheld send blocks past the recv deadline so this rank's
        // own round budget expires — real head-of-line blocking stalls the
        // sender too, and that is what keeps live and netsim trajectories
        // aligned (the rank *observes* its own disruption).
        if self.reorder_armed {
            if payload.first() == Some(&1) {
                // Probe: release withheld data first (per-peer FIFO
                // intact), then the probe itself, then stop reordering.
                let withheld = std::mem::take(&mut self.reorder_buffer);
                for (peer, frame) in withheld {
                    let _ = self.inner.send(peer, &frame);
                }
                self.reorder_armed = false;
                self.reorder_stalled = false;
                return self.inner.send(to, payload);
            }
            self.reorder_buffer.push((to, payload.to_vec()));
            if !self.reorder_stalled {
                self.reorder_stalled = true;
                obs::hot().faults_reorder_total.inc();
                crate::util::poller::sleep_until(
                    Instant::now()
                        + self.recv_timeout
                        + self.recv_timeout / 4
                        + Duration::from_millis(20),
                );
            }
            return Ok(());
        }
        // Duplication: record data frames (never probes — a replayed probe
        // would fake a recovery) for re-delivery at the next step.
        if self.dup_recording && payload.first() == Some(&0) {
            self.dup_buffer.push((to, payload.to_vec()));
        }
        self.inner.send(to, payload)
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        if self.killed {
            return Err(self.dead_err());
        }
        self.inner.recv(from)
    }

    fn recv_into(&mut self, from: usize, buf: &mut Vec<u8>) -> Result<()> {
        if self.killed {
            return Err(self.dead_err());
        }
        // Forward (instead of taking the recv-then-copy default) so the
        // inner transport's receive-buffer recycling stays on the path.
        self.inner.recv_into(from, buf)
    }

    fn take_observations(&mut self) -> Vec<TransferObs> {
        self.inner.take_observations()
    }

    fn take_wire_wait_ns(&mut self) -> u64 {
        self.inner.take_wire_wait_ns()
    }

    fn set_recv_timeout(&mut self, timeout: Duration) {
        self.recv_timeout = timeout;
        self.inner.set_recv_timeout(timeout);
    }

    fn shutdown(&mut self) -> Result<()> {
        self.inner.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::LoopbackTransport;

    fn pair() -> (Box<dyn Transport>, Box<dyn Transport>) {
        let mut mesh = LoopbackTransport::mesh(2);
        let b = mesh.pop().unwrap();
        let a = mesh.pop().unwrap();
        (Box::new(a), Box::new(b))
    }

    #[test]
    fn empty_spec_is_a_pass_through() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, Vec::new());
        for step in 0..3 {
            a.on_step(step);
            a.send(1, b"ping").unwrap();
            assert_eq!(b.recv(0).unwrap(), b"ping");
        }
        assert!(!a.is_killed());
        assert_eq!(a.take_observations().len(), 3);
    }

    #[test]
    fn kill_fires_at_its_step_and_peers_observe_it() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, vec![FaultSpec::KillAtStep { step: 2 }]);
        a.on_step(0);
        a.send(1, b"alive").unwrap();
        assert_eq!(b.recv(0).unwrap(), b"alive");
        a.on_step(1);
        a.on_step(2);
        assert!(a.is_killed());
        let e = a.send(1, b"x").unwrap_err();
        assert!(format!("{e}").contains("injected-kill"), "{e}");
        assert!(a.recv(1).is_err());
        // The peer sees the shutdown, not a silent void.
        let e = b.recv(0).unwrap_err();
        assert!(format!("{e}").contains("shut down"), "{e}");
    }

    #[test]
    fn stall_delays_exactly_one_step() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, vec![FaultSpec::StallAtStep { step: 1, stall_ms: 30 }]);
        a.on_step(0);
        let t0 = std::time::Instant::now();
        a.send(1, b"fast").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(20));
        a.on_step(1);
        let t0 = std::time::Instant::now();
        a.send(1, b"slow").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(30), "stall not applied");
        // Only the first send of the step stalls.
        let t0 = std::time::Instant::now();
        a.send(1, b"fast again").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(20));
        for want in [&b"fast"[..], b"slow", b"fast again"] {
            assert_eq!(b.recv(0).unwrap(), want);
        }
    }

    #[test]
    fn flap_blocks_sends_until_the_link_heals() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, vec![FaultSpec::FlapAtStep { step: 0, down_ms: 40 }]);
        a.on_step(0);
        let t0 = std::time::Instant::now();
        a.send(1, b"delayed").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(40), "flap not applied");
        assert_eq!(b.recv(0).unwrap(), b"delayed");
        // Healed: later sends are immediate.
        let t0 = std::time::Instant::now();
        a.send(1, b"healed").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(20));
        assert_eq!(b.recv(0).unwrap(), b"healed");
    }

    /// Duplication records data frames (kind byte 0) during its step and
    /// re-delivers them — and only them — at the next step boundary.
    #[test]
    fn duplicate_resends_previous_step_data_frames() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, vec![FaultSpec::DuplicateAtStep { step: 0 }]);
        a.on_step(0);
        a.send(1, &[0, 1, 2, 3]).unwrap(); // data — recorded
        a.send(1, &[1, 9]).unwrap(); // probe — never recorded
        assert_eq!(b.recv(0).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.recv(0).unwrap(), vec![1, 9]);
        // Step boundary: the duplicated data frame arrives again, verbatim.
        a.on_step(1);
        assert_eq!(b.recv(0).unwrap(), vec![0, 1, 2, 3]);
        a.send(1, &[0, 7]).unwrap();
        assert_eq!(b.recv(0).unwrap(), vec![0, 7]);
        // Nothing else was replayed (the probe stayed single-shot).
        a.on_step(2);
        b.set_recv_timeout(Duration::from_millis(30));
        assert!(b.recv(0).is_err(), "probe frame was duplicated");
    }

    /// Reordering withholds data frames, stalls the sender past its own
    /// recv deadline once, and releases everything — data first, then the
    /// probe, per-peer FIFO intact — on the first probe send.
    #[test]
    fn reorder_withholds_data_until_first_probe() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, vec![FaultSpec::ReorderAtStep { step: 1 }]);
        a.set_recv_timeout(Duration::from_millis(40));
        a.on_step(0);
        a.send(1, &[0, 7]).unwrap();
        assert_eq!(b.recv(0).unwrap(), vec![0, 7]);
        a.on_step(1);
        // First withheld send blocks past the 40 ms recv deadline.
        let t0 = Instant::now();
        a.send(1, &[0, 8]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(60), "no head-of-line stall");
        b.set_recv_timeout(Duration::from_millis(30));
        assert!(b.recv(0).is_err(), "withheld frame leaked");
        // Later withheld sends don't stall again.
        let t0 = Instant::now();
        a.send(1, &[0, 9]).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(20));
        // The probe releases: data in order, then the probe.
        a.send(1, &[1, 1]).unwrap();
        b.set_recv_timeout(Duration::from_millis(500));
        assert_eq!(b.recv(0).unwrap(), vec![0, 8]);
        assert_eq!(b.recv(0).unwrap(), vec![0, 9]);
        assert_eq!(b.recv(0).unwrap(), vec![1, 1]);
    }

    /// A partial send delivers the torn prefix, then the endpoint is dead
    /// exactly like a kill — the peer sees bytes-then-disconnect.
    #[test]
    fn partial_send_truncates_then_kills() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(
            a,
            vec![FaultSpec::PartialSendAtStep { step: 0, keep_bytes: 3 }],
        );
        a.on_step(0);
        let e = a.send(1, &[9, 9, 9, 9, 9, 9]).unwrap_err();
        assert!(format!("{e}").contains("injected-kill"), "{e}");
        assert!(a.is_killed());
        assert!(a.send(1, b"x").is_err(), "dead endpoint accepted a send");
        // The peer drains the torn prefix, then observes the disconnect.
        assert_eq!(b.recv(0).unwrap(), vec![9, 9, 9]);
        let e = b.recv(0).unwrap_err();
        assert!(format!("{e}").contains("shut down"), "{e}");
    }

    /// ISSUE satellite: schedule firings are quantifiable — each fault
    /// that actually fires ticks its registry counter. (The registry is
    /// process-global and shared across tests, so assert deltas.)
    #[test]
    fn fault_firings_tick_registry_counters() {
        let m = crate::obs::hot();
        let kills = m.faults_kill_total.get();
        let stalls = m.faults_stall_total.get();
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(
            a,
            vec![
                FaultSpec::StallAtStep { step: 0, stall_ms: 1 },
                FaultSpec::KillAtStep { step: 1 },
            ],
        );
        a.on_step(0);
        a.send(1, &[0, 1]).unwrap();
        assert_eq!(b.recv(0).unwrap(), vec![0, 1]);
        assert!(m.faults_stall_total.get() >= stalls + 1, "stall not counted");
        a.on_step(1);
        assert!(m.faults_kill_total.get() >= kills + 1, "kill not counted");
    }

    #[test]
    fn schedule_slices_per_rank() {
        let schedule = FaultSchedule {
            kills: vec![(2, 5)],
            stalls: vec![(1, 3, 50)],
            flaps: vec![(1, 7, 80)],
            duplicates: vec![(0, 2)],
            reorders: vec![(1, 9)],
            partial_kills: vec![(3, 4, 5)],
        };
        assert_eq!(
            schedule.specs_for(1),
            vec![
                FaultSpec::StallAtStep { step: 3, stall_ms: 50 },
                FaultSpec::FlapAtStep { step: 7, down_ms: 80 },
                FaultSpec::ReorderAtStep { step: 9 },
            ]
        );
        assert_eq!(schedule.specs_for(2), vec![FaultSpec::KillAtStep { step: 5 }]);
        assert_eq!(
            schedule.specs_for(0),
            vec![FaultSpec::DuplicateAtStep { step: 2 }]
        );
        assert_eq!(
            schedule.specs_for(3),
            vec![FaultSpec::PartialSendAtStep { step: 4, keep_bytes: 5 }]
        );
        assert_eq!(schedule.kill_step(2), Some(5));
        assert_eq!(schedule.kill_step(1), None);
        // A partial kill is still a kill for scheduling purposes.
        assert_eq!(schedule.kill_step(3), Some(4));
    }
}
