//! Deterministic fault injection at the transport seam: a
//! [`Transport`] wrapper that kills, stalls, or flaps this endpoint on a
//! step-indexed schedule, so live runs and netsim runs can exercise the
//! *same* failure scenario ([`super::sim_trajectory`] is the simulator
//! mirror of the same schedule).
//!
//! Faults are keyed by training step, not wall clock — the worker loop
//! reports its step via [`FaultInjector::on_step`], which is what makes a
//! chaos run replayable: the same schedule produces the same epoch/live
//! trajectory every time (wall-clock only shifts *when* the recovery
//! happens, never *what* it decides).

use super::FaultSchedule;
use crate::transport::{Transport, TransferObs};
use crate::util::error::{anyhow, Result};
use std::time::{Duration, Instant};

/// One fault on one rank's endpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSpec {
    /// From the start of `step`, the endpoint is dead: every send/recv
    /// errors and the inner transport is shut down (peers observe a
    /// disconnect or a recv timeout).
    KillAtStep { step: usize },
    /// A straggler: the first send of `step` is delayed by `stall_ms`
    /// (local compute hiccup — GC pause, preemption). Below the group's
    /// recv timeout it is absorbed as a slow round; above it, peers run a
    /// recovery that finds everyone alive.
    StallAtStep { step: usize, stall_ms: u64 },
    /// A flapping link: from the first send at/after `step`, the link is
    /// down for `down_ms` of wall clock — sends block until the link heals
    /// (outage buffering), so peers time out, recover, and the replayed
    /// round finds the rank alive again.
    FlapAtStep { step: usize, down_ms: u64 },
}

impl FaultSpec {
    fn step(&self) -> usize {
        match self {
            FaultSpec::KillAtStep { step }
            | FaultSpec::StallAtStep { step, .. }
            | FaultSpec::FlapAtStep { step, .. } => *step,
        }
    }
}

/// A [`Transport`] wrapper executing this rank's slice of a
/// [`FaultSchedule`]. An empty spec list is a pass-through, so the worker
/// loop always runs with the injector (and its membership checks) on.
pub struct FaultInjector {
    inner: Box<dyn Transport>,
    specs: Vec<FaultSpec>,
    killed: bool,
    /// Pending one-shot stall (ms), armed by [`Self::on_step`], consumed
    /// by the next send.
    stall_pending: Option<u64>,
    /// The flap outage end, armed by [`Self::on_step`]; sends before it
    /// block until it passes.
    flap_until: Option<Instant>,
}

impl FaultInjector {
    pub fn new(inner: Box<dyn Transport>, specs: Vec<FaultSpec>) -> FaultInjector {
        FaultInjector {
            inner,
            specs,
            killed: false,
            stall_pending: None,
            flap_until: None,
        }
    }

    /// Wrap with this rank's slice of a whole-group schedule.
    pub fn from_schedule(inner: Box<dyn Transport>, schedule: &FaultSchedule) -> FaultInjector {
        let rank = inner.rank();
        FaultInjector::new(inner, schedule.specs_for(rank))
    }

    /// The worker loop is entering training step `step` — arm any faults
    /// scheduled for it.
    pub fn on_step(&mut self, step: usize) {
        let (mut kill, mut stall, mut flap) = (false, None, None);
        for spec in &self.specs {
            if spec.step() != step {
                continue;
            }
            match *spec {
                FaultSpec::KillAtStep { .. } => kill = true,
                FaultSpec::StallAtStep { stall_ms, .. } => stall = Some(stall_ms),
                FaultSpec::FlapAtStep { down_ms, .. } => flap = Some(down_ms),
            }
        }
        if kill {
            self.killed = true;
            let _ = self.inner.shutdown();
        }
        if let Some(ms) = stall {
            self.stall_pending = Some(ms);
        }
        if let Some(ms) = flap {
            self.flap_until = Some(Instant::now() + Duration::from_millis(ms));
        }
    }

    /// Did a `KillAtStep` fire? The worker uses this to distinguish its
    /// own planned death (return a partial trace) from a real failure
    /// (propagate the error).
    pub fn is_killed(&self) -> bool {
        self.killed
    }

    fn dead_err(&self) -> crate::util::error::Error {
        anyhow!("injected-kill: rank {} is dead", self.inner.rank())
    }
}

impl Transport for FaultInjector {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn group_size(&self) -> usize {
        self.inner.group_size()
    }

    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        if self.killed {
            return Err(self.dead_err());
        }
        if let Some(ms) = self.stall_pending.take() {
            std::thread::sleep(Duration::from_millis(ms));
        }
        if let Some(until) = self.flap_until {
            let now = Instant::now();
            if now < until {
                std::thread::sleep(until - now);
            }
            self.flap_until = None;
        }
        self.inner.send(to, payload)
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        if self.killed {
            return Err(self.dead_err());
        }
        self.inner.recv(from)
    }

    fn recv_into(&mut self, from: usize, buf: &mut Vec<u8>) -> Result<()> {
        if self.killed {
            return Err(self.dead_err());
        }
        // Forward (instead of taking the recv-then-copy default) so the
        // inner transport's receive-buffer recycling stays on the path.
        self.inner.recv_into(from, buf)
    }

    fn take_observations(&mut self) -> Vec<TransferObs> {
        self.inner.take_observations()
    }

    fn set_recv_timeout(&mut self, timeout: Duration) {
        self.inner.set_recv_timeout(timeout);
    }

    fn shutdown(&mut self) -> Result<()> {
        self.inner.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::LoopbackTransport;

    fn pair() -> (Box<dyn Transport>, Box<dyn Transport>) {
        let mut mesh = LoopbackTransport::mesh(2);
        let b = mesh.pop().unwrap();
        let a = mesh.pop().unwrap();
        (Box::new(a), Box::new(b))
    }

    #[test]
    fn empty_spec_is_a_pass_through() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, Vec::new());
        for step in 0..3 {
            a.on_step(step);
            a.send(1, b"ping").unwrap();
            assert_eq!(b.recv(0).unwrap(), b"ping");
        }
        assert!(!a.is_killed());
        assert_eq!(a.take_observations().len(), 3);
    }

    #[test]
    fn kill_fires_at_its_step_and_peers_observe_it() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, vec![FaultSpec::KillAtStep { step: 2 }]);
        a.on_step(0);
        a.send(1, b"alive").unwrap();
        assert_eq!(b.recv(0).unwrap(), b"alive");
        a.on_step(1);
        a.on_step(2);
        assert!(a.is_killed());
        let e = a.send(1, b"x").unwrap_err();
        assert!(format!("{e}").contains("injected-kill"), "{e}");
        assert!(a.recv(1).is_err());
        // The peer sees the shutdown, not a silent void.
        let e = b.recv(0).unwrap_err();
        assert!(format!("{e}").contains("shut down"), "{e}");
    }

    #[test]
    fn stall_delays_exactly_one_step() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, vec![FaultSpec::StallAtStep { step: 1, stall_ms: 30 }]);
        a.on_step(0);
        let t0 = std::time::Instant::now();
        a.send(1, b"fast").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(20));
        a.on_step(1);
        let t0 = std::time::Instant::now();
        a.send(1, b"slow").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(30), "stall not applied");
        // Only the first send of the step stalls.
        let t0 = std::time::Instant::now();
        a.send(1, b"fast again").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(20));
        for want in [&b"fast"[..], b"slow", b"fast again"] {
            assert_eq!(b.recv(0).unwrap(), want);
        }
    }

    #[test]
    fn flap_blocks_sends_until_the_link_heals() {
        let (a, mut b) = pair();
        let mut a = FaultInjector::new(a, vec![FaultSpec::FlapAtStep { step: 0, down_ms: 40 }]);
        a.on_step(0);
        let t0 = std::time::Instant::now();
        a.send(1, b"delayed").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(40), "flap not applied");
        assert_eq!(b.recv(0).unwrap(), b"delayed");
        // Healed: later sends are immediate.
        let t0 = std::time::Instant::now();
        a.send(1, b"healed").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(20));
        assert_eq!(b.recv(0).unwrap(), b"healed");
    }

    #[test]
    fn schedule_slices_per_rank() {
        let schedule = FaultSchedule {
            kills: vec![(2, 5)],
            stalls: vec![(1, 3, 50)],
            flaps: vec![(1, 7, 80)],
        };
        assert_eq!(
            schedule.specs_for(1),
            vec![
                FaultSpec::StallAtStep { step: 3, stall_ms: 50 },
                FaultSpec::FlapAtStep { step: 7, down_ms: 80 },
            ]
        );
        assert_eq!(schedule.specs_for(2), vec![FaultSpec::KillAtStep { step: 5 }]);
        assert!(schedule.specs_for(0).is_empty());
        assert_eq!(schedule.kill_step(2), Some(5));
        assert_eq!(schedule.kill_step(1), None);
    }
}
