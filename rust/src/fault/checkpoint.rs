//! Compressor-state checkpoints: everything a rejoining rank needs to
//! resume compression without corrupting convergence.
//!
//! Error feedback makes Algorithm 2 *stateful*: the residual carries the
//! gradient mass every past step withheld, and the selection caches
//! (top-k threshold hint, pruning threshold) steer which coordinates the
//! fast paths pick. A rank that rejoins with a blank compressor would
//! re-inject none of its residual (convergence bias) and reselect from
//! scratch (divergence from the group's deterministic trajectory). A
//! [`Checkpoint`] snapshots the full
//! [`CompressorState`](crate::compress::CompressorState) — per tensor or
//! per bucket — so a restored compressor continues **bit-identically**
//! to the original (tested below, fused and staged paths both).
//!
//! Wire format (little-endian, versioned):
//! `[u32 magic "NSCK"][u32 version][u64 epoch][u64 step][u32 n_states]`
//! then per state: `[u32 n][u8 flags][f32 threshold][f64 prune_rate]
//! [f32 prune_th][u32 prune_age][f64 grad_l2][n × f32 residual]`
//! (flag bits mark which of the optional fields are present; absent ones
//! still occupy their slot, zero-filled, to keep offsets static).

use crate::compress::CompressorState;
use crate::util::error::{anyhow, Result};

/// Checkpoint magic: `"NSCK"` little-endian.
pub const CHECKPOINT_MAGIC: u32 = 0x4b43_534e;
const VERSION: u32 = 1;

const FLAG_THRESHOLD: u8 = 1 << 0;
const FLAG_PRUNE: u8 = 1 << 1;
const FLAG_L2: u8 = 1 << 2;

/// A rank's compression state at a membership epoch + training step:
/// one [`CompressorState`] per tensor (monolithic path) or per bucket
/// (pipelined path), in layout order.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub epoch: u64,
    pub step: u64,
    pub states: Vec<CompressorState>,
}

impl Checkpoint {
    pub fn new(epoch: u64, step: u64, states: Vec<CompressorState>) -> Checkpoint {
        Checkpoint {
            epoch,
            step,
            states,
        }
    }

    /// Serialize to the versioned binary format.
    pub fn encode(&self) -> Vec<u8> {
        let residuals: usize = self.states.iter().map(|s| s.residual.len()).sum();
        let mut out = Vec::with_capacity(24 + self.states.len() * 29 + residuals * 4);
        out.extend_from_slice(&CHECKPOINT_MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&(self.states.len() as u32).to_le_bytes());
        for s in &self.states {
            out.extend_from_slice(&(s.residual.len() as u32).to_le_bytes());
            let mut flags = 0u8;
            if s.last_threshold.is_some() {
                flags |= FLAG_THRESHOLD;
            }
            if s.prune_cache.is_some() {
                flags |= FLAG_PRUNE;
            }
            if s.last_grad_l2.is_some() {
                flags |= FLAG_L2;
            }
            out.push(flags);
            out.extend_from_slice(&s.last_threshold.unwrap_or(0.0).to_le_bytes());
            let (rate, th) = s.prune_cache.unwrap_or((0.0, 0.0));
            out.extend_from_slice(&rate.to_le_bytes());
            out.extend_from_slice(&th.to_le_bytes());
            out.extend_from_slice(&s.prune_cache_age.to_le_bytes());
            out.extend_from_slice(&s.last_grad_l2.unwrap_or(0.0).to_le_bytes());
            for x in &s.residual {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    /// Parse a [`Checkpoint::encode`] buffer; corruption yields named
    /// errors, never garbage state.
    pub fn decode(buf: &[u8]) -> Result<Checkpoint> {
        let mut r = Reader { buf, at: 0 };
        let magic = r.u32()?;
        if magic != CHECKPOINT_MAGIC {
            return Err(anyhow!("bad checkpoint magic {magic:#010x}"));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(anyhow!("unsupported checkpoint version {version}"));
        }
        let epoch = r.u64()?;
        let step = r.u64()?;
        let n_states = r.u32()? as usize;
        let mut states = Vec::with_capacity(n_states.min(1 << 16));
        for i in 0..n_states {
            let n = r.u32()? as usize;
            let flags = r.u8()?;
            if flags & !(FLAG_THRESHOLD | FLAG_PRUNE | FLAG_L2) != 0 {
                return Err(anyhow!("state {i}: unknown flag bits {flags:#04x}"));
            }
            let threshold = r.f32()?;
            let prune_rate = r.f64()?;
            let prune_th = r.f32()?;
            let prune_age = r.u32()?;
            let grad_l2 = r.f64()?;
            if r.remaining() < n * 4 {
                return Err(anyhow!(
                    "state {i}: truncated residual ({} bytes left, need {})",
                    r.remaining(),
                    n * 4
                ));
            }
            let mut residual = Vec::with_capacity(n);
            for _ in 0..n {
                residual.push(r.f32()?);
            }
            states.push(CompressorState {
                residual,
                last_threshold: (flags & FLAG_THRESHOLD != 0).then_some(threshold),
                prune_cache: (flags & FLAG_PRUNE != 0).then_some((prune_rate, prune_th)),
                prune_cache_age: prune_age,
                last_grad_l2: (flags & FLAG_L2 != 0).then_some(grad_l2),
            });
        }
        if r.remaining() != 0 {
            return Err(anyhow!("{} trailing bytes after checkpoint", r.remaining()));
        }
        Ok(Checkpoint {
            epoch,
            step,
            states,
        })
    }
}

/// Bounds-checked little-endian cursor.
struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Reader<'_> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.remaining() < n {
            return Err(anyhow!(
                "truncated checkpoint: need {n} bytes at offset {}, have {}",
                self.at,
                self.remaining()
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::bucket::{BucketLayout, BucketedCompressor};
    use crate::compress::{CompressionConfig, NetSenseCompressor, Workspace, WorkspacePool};
    use crate::util::rng::Pcg64;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg64::seeded(seed);
        let mut v = vec![0f32; n];
        r.fill_normal_f32(&mut v, 0.0, 1.0);
        v
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut c = NetSenseCompressor::new(500, CompressionConfig::default());
        c.compress(&randn(500, 1), &randn(500, 2), 0.1);
        let ck = Checkpoint::new(3, 42, vec![c.export_state()]);
        let decoded = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(decoded, ck);
        // A never-used compressor has no cached fields: all flags off.
        let fresh = NetSenseCompressor::new(8, CompressionConfig::default());
        let ck = Checkpoint::new(0, 0, vec![fresh.export_state()]);
        let decoded = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(decoded.states[0].last_threshold, None);
        assert_eq!(decoded.states[0].prune_cache, None);
        assert_eq!(decoded.states[0].last_grad_l2, None);
    }

    #[test]
    fn decode_rejects_corruption() {
        let ck = Checkpoint::new(1, 2, vec![CompressorState {
            residual: vec![1.0, 2.0],
            last_threshold: Some(0.5),
            prune_cache: None,
            prune_cache_age: 3,
            last_grad_l2: Some(2.2),
        }]);
        let wire = ck.encode();
        assert!(Checkpoint::decode(&wire[..4]).is_err()); // truncated
        let mut bad = wire.clone();
        bad[0] ^= 0xff; // magic
        assert!(Checkpoint::decode(&bad).is_err());
        let mut bad = wire.clone();
        bad[4] = 99; // version
        assert!(Checkpoint::decode(&bad).is_err());
        let mut long = wire.clone();
        long.push(0); // trailing garbage
        assert!(Checkpoint::decode(&long).is_err());
        let mut short = wire;
        short.pop(); // torn residual
        assert!(Checkpoint::decode(&short).is_err());
    }

    /// The rejoin contract: a compressor restored from a checkpoint
    /// continues bit-identically — staged and fused paths both.
    #[test]
    fn restored_compressor_resumes_bit_identically() {
        let n = 4_000;
        let w = randn(n, 10);
        let mut g = randn(n, 11);
        let mut drift = Pcg64::seeded(12);
        let mut original = NetSenseCompressor::new(n, CompressionConfig::default());
        // A few live steps accumulate residual + caches.
        for step in 0..5 {
            for x in g.iter_mut() {
                *x += 0.05 * drift.normal() as f32;
            }
            original.compress(&g, &w, if step % 2 == 0 { 0.1 } else { 0.02 });
        }
        // Snapshot → wire → restore into a blank compressor (the rank
        // that rejoins after a kill).
        let wire = Checkpoint::new(2, 5, vec![original.export_state()]).encode();
        let ck = Checkpoint::decode(&wire).unwrap();
        let mut rejoined = NetSenseCompressor::new(n, CompressionConfig::default());
        rejoined.import_state(&ck.states[0]);
        // Both continue on identical inputs: identical wire bytes, via
        // the staged path on one and the fused path on the other.
        let mut ws = Workspace::new();
        for step in 0..6 {
            for x in g.iter_mut() {
                *x += 0.05 * drift.normal() as f32;
            }
            let ratio = [0.1, 0.05, 0.01][step % 3];
            let staged = original.compress(&g, &w, ratio);
            let mut fused_wire = Vec::new();
            let out = rejoined.compress_payload_into(&g, &w, ratio, &mut ws, &mut fused_wire);
            assert_eq!(
                staged.payload.encode(),
                fused_wire,
                "step {step}: restored compressor diverged"
            );
            assert_eq!(staged.wire_bytes, out.wire_bytes);
        }
        assert_eq!(
            original.residual_norm(),
            rejoined.residual_norm(),
            "residuals diverged after resume"
        );
    }

    #[test]
    fn bucketed_state_roundtrips_through_checkpoint() {
        let n = 3_000;
        let layout = BucketLayout::new(n, 1_000);
        let w = randn(n, 20);
        let mut pool = WorkspacePool::new(1);
        let mut original = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
        for step in 0..4 {
            original.compress_frames(&randn(n, 30 + step), &w, 0.05, &mut pool);
        }
        let ck = Checkpoint::new(1, 4, original.export_state());
        let ck = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(ck.states.len(), layout.n_buckets());
        let mut rejoined = BucketedCompressor::new(layout, CompressionConfig::default());
        rejoined.import_state(&ck.states);
        let g = randn(n, 99);
        let (_, frames_a) = original.compress_frames(&g, &w, 0.05, &mut pool);
        let frames_a: Vec<Vec<u8>> = frames_a.to_vec();
        let (_, frames_b) = rejoined.compress_frames(&g, &w, 0.05, &mut pool);
        assert_eq!(frames_a, frames_b.to_vec(), "bucketed resume diverged");
    }

    #[test]
    #[should_panic(expected = "residual snapshot length mismatch")]
    fn import_rejects_wrong_length() {
        let mut c = NetSenseCompressor::new(10, CompressionConfig::default());
        let other = NetSenseCompressor::new(11, CompressionConfig::default());
        c.import_state(&other.export_state());
    }

    /// Corruption property: every corruption class maps to its *named*
    /// error — and a failed restore attempt leaves the engine untouched,
    /// so retrying with the pristine blob still resumes bit-identically
    /// (a failed `decode` returns no [`Checkpoint`] at all; there is
    /// nothing to import).
    #[test]
    fn corruption_yields_named_errors_and_a_clean_retry_still_resumes() {
        let n = 600;
        let w = randn(n, 40);
        let mut g = randn(n, 41);
        let mut original = NetSenseCompressor::new(n, CompressionConfig::default());
        for _ in 0..3 {
            original.compress(&g, &w, 0.1);
        }
        let wire = Checkpoint::new(1, 3, vec![original.export_state()]).encode();

        let named = |buf: &[u8]| format!("{}", Checkpoint::decode(buf).unwrap_err());
        // Truncated blob: the residual length check names the shortfall.
        assert!(named(&wire[..wire.len() - 3]).contains("truncated residual"));
        // Truncated header: the bounds-checked reader names the offset.
        assert!(named(&wire[..13]).contains("truncated checkpoint"));
        // Wrong version.
        let mut bad = wire.clone();
        bad[4] = 9;
        assert!(named(&bad).contains("unsupported checkpoint version 9"));
        // Bit-flipped CompressorState: the flags byte sits right after
        // the 28-byte header + the state's 4-byte residual length.
        let mut bad = wire.clone();
        bad[32] |= 0x80;
        assert!(named(&bad).contains("unknown flag bits"));
        // Bad magic and trailing garbage.
        let mut bad = wire.clone();
        bad[1] ^= 0x40;
        assert!(named(&bad).contains("bad checkpoint magic"));
        let mut long = wire.clone();
        long.push(0);
        assert!(named(&long).contains("trailing bytes after checkpoint"));

        // The failed attempts had no side effects: restoring from the
        // pristine blob afterwards still continues bit-identically.
        let ck = Checkpoint::decode(&wire).unwrap();
        let mut rejoined = NetSenseCompressor::new(n, CompressionConfig::default());
        rejoined.import_state(&ck.states[0]);
        let mut ws = Workspace::new();
        let mut drift = Pcg64::seeded(42);
        for x in g.iter_mut() {
            *x += 0.05 * drift.normal() as f32;
        }
        let staged = original.compress(&g, &w, 0.05);
        let mut fused_wire = Vec::new();
        rejoined.compress_payload_into(&g, &w, 0.05, &mut ws, &mut fused_wire);
        assert_eq!(staged.payload.encode(), fused_wire, "retry after corruption diverged");
    }

    /// Fuzz property: `decode` is total over mutations of *real*
    /// compressor snapshots (richer than the synthetic states the fuzz
    /// generator builds), and whatever it accepts re-canonicalizes —
    /// [`crate::testing::fuzz::probe_checkpoint`] asserts the
    /// decode∘encode idempotence contract internally.
    #[test]
    fn mutated_live_snapshots_never_panic_the_decoder() {
        use crate::testing::fuzz::{fuzz_iters, fuzz_seed, ByteMutator, SplitMix64};
        let n = 256;
        let w = randn(n, 50);
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut rng = SplitMix64::new(fuzz_seed() ^ 0xc4ec);
        let mut mutator = ByteMutator::new(fuzz_seed() ^ 0x6d75_7461);
        let mut rejected = 0usize;
        for i in 0..fuzz_iters(200) {
            c.compress(&randn(n, 60 + i as u64), &w, 0.1);
            let pristine = Checkpoint::new(rng.next(), rng.next(), vec![c.export_state()]);
            let mut wire = pristine.encode();
            crate::testing::fuzz::probe_checkpoint(&wire)
                .unwrap_or_else(|e| panic!("pristine snapshot rejected: {e}"));
            mutator.mutate(&mut wire);
            if let Err(e) = crate::testing::fuzz::probe_checkpoint(&wire) {
                assert!(!e.is_empty(), "corruption must carry a named error");
                rejected += 1;
            }
        }
        assert!(rejected > 0, "mutator never produced a rejected snapshot");
    }
}
