//! The metrics registry: named atomic counters, gauges, and log₂-bucketed
//! histograms with lock-free hot-path recording and a Prometheus-text
//! snapshot exporter.
//!
//! Recording is a relaxed atomic RMW — no locks, no allocation, safe from
//! any thread (worker threads of one live run share the process-global
//! registry, so counters aggregate across ranks). Registration
//! ([`Registry::counter`] and friends) takes a mutex and leaks one small
//! box per metric — it happens once per name, at startup or in a warmup
//! loop, never on the hot path. [`hot`] pre-registers every well-known
//! metric of the runtime layers so hot paths pay a single static deref.
//!
//! Histogram buckets are powers of two: bucket 0 holds exact zeros,
//! bucket *i* ≥ 1 holds `[2^(i−1), 2^i − 1]`, the last bucket (64) tops
//! out at `u64::MAX`. Two orders of magnitude per ~6.6 buckets is plenty
//! for latency/size distributions, and the bucket index is two ALU ops
//! (`leading_zeros`), no search, no float math.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter {
            value: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in an `AtomicU64`).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge {
            bits: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Number of histogram buckets: one for zero plus one per bit position.
pub const N_BUCKETS: usize = 65;

/// Log₂-bucketed histogram of `u64` observations (latencies in ns/µs,
/// sizes in bytes). `sum` wraps on overflow (relevant only for
/// `u64::MAX`-scale observations); counts are exact.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index of `v`: 0 for 0, else `64 − leading_zeros(v)` — so
    /// bucket *i* ≥ 1 covers `[2^(i−1), 2^i − 1]`.
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros()) as usize
        }
    }

    /// Inclusive upper bound of bucket `i` (the value a percentile
    /// estimate reports).
    pub fn bucket_upper_bound(i: usize) -> u64 {
        match i {
            0 => 0,
            _ if i >= 64 => u64::MAX,
            _ => (1u64 << i) - 1,
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Raw per-bucket counts (snapshot; concurrent observes may land
    /// between loads).
    pub fn bucket_counts(&self) -> [u64; N_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Upper-bound percentile estimate: the inclusive upper bound of the
    /// first bucket whose cumulative count reaches `q` of the total
    /// (`q` clamped to `[0, 1]`). Returns 0 on an empty histogram.
    /// Monotone in `q` by construction — cumulative counts and bucket
    /// bounds both only grow.
    pub fn percentile(&self, q: f64) -> u64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * total as f64).ceil() as u64).max(1).min(total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_upper_bound(i);
            }
        }
        u64::MAX
    }

    /// Interpolated quantile estimate: finds the bucket holding the
    /// `q`-th observation (by rank, `q` clamped to `[0, 1]`) and
    /// interpolates linearly within the bucket's `[2^(i−1), 2^i − 1]`
    /// range by the rank's position among the bucket's observations —
    /// a smoother estimate than [`Self::percentile`]'s upper bound,
    /// always ≤ it. Returns 0.0 on an empty histogram. This is what the
    /// Prometheus exporter's `_p50`/`_p95`/`_p99` summary lines and the
    /// analyzer report ([`crate::obs::analyze`]) use.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * total as f64).max(1.0);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = cum;
            cum += c;
            if cum as f64 >= target {
                let lo = if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 };
                let hi = Self::bucket_upper_bound(i) as f64;
                let frac = (target - before as f64) / c as f64;
                return lo + frac * (hi - lo);
            }
        }
        Self::bucket_upper_bound(N_BUCKETS - 1) as f64
    }
}

enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

struct Entry {
    name: &'static str,
    help: &'static str,
    metric: Metric,
}

/// A named collection of metrics. Most code uses the process [`registry`]
/// (and the [`hot`] struct over it); tests construct their own to assert
/// exact values without cross-test interference.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register (or look up) a counter. Idempotent by name; registering
    /// the same name as a different metric kind is a programming error
    /// and panics.
    pub fn counter(&self, name: &'static str, help: &'static str) -> &'static Counter {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            match e.metric {
                Metric::Counter(c) => return c,
                _ => panic!("metric `{name}` already registered with a different kind"),
            }
        }
        let c: &'static Counter = Box::leak(Box::new(Counter::new()));
        entries.push(Entry {
            name,
            help,
            metric: Metric::Counter(c),
        });
        c
    }

    /// Register (or look up) a gauge — same contract as [`Self::counter`].
    pub fn gauge(&self, name: &'static str, help: &'static str) -> &'static Gauge {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            match e.metric {
                Metric::Gauge(g) => return g,
                _ => panic!("metric `{name}` already registered with a different kind"),
            }
        }
        let g: &'static Gauge = Box::leak(Box::new(Gauge::new()));
        entries.push(Entry {
            name,
            help,
            metric: Metric::Gauge(g),
        });
        g
    }

    /// Register (or look up) a histogram — same contract as
    /// [`Self::counter`].
    pub fn histogram(&self, name: &'static str, help: &'static str) -> &'static Histogram {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            match e.metric {
                Metric::Histogram(h) => return h,
                _ => panic!("metric `{name}` already registered with a different kind"),
            }
        }
        let h: &'static Histogram = Box::leak(Box::new(Histogram::new()));
        entries.push(Entry {
            name,
            help,
            metric: Metric::Histogram(h),
        });
        h
    }

    /// Snapshot every metric in Prometheus text exposition format
    /// (`# HELP` / `# TYPE` / samples; histograms as cumulative
    /// `_bucket{le=…}` plus `_sum`/`_count`). Names sort alphabetically
    /// so snapshots diff cleanly.
    pub fn prometheus(&self) -> String {
        let entries = self.entries.lock().unwrap();
        let mut order: Vec<usize> = (0..entries.len()).collect();
        order.sort_by_key(|&i| entries[i].name);
        let mut out = String::new();
        for &i in &order {
            let e = &entries[i];
            match e.metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
                    out.push_str(&format!("# TYPE {} counter\n", e.name));
                    out.push_str(&format!("{} {}\n", e.name, c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
                    out.push_str(&format!("# TYPE {} gauge\n", e.name));
                    out.push_str(&format!("{} {}\n", e.name, g.get()));
                }
                Metric::Histogram(h) => {
                    out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
                    out.push_str(&format!("# TYPE {} histogram\n", e.name));
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (b, &c) in counts.iter().enumerate() {
                        if c == 0 && b != 0 {
                            // Empty interior buckets add nothing to a
                            // cumulative export; keep the snapshot short.
                            continue;
                        }
                        cum += c;
                        out.push_str(&format!(
                            "{}_bucket{{le=\"{}\"}} {}\n",
                            e.name,
                            Histogram::bucket_upper_bound(b),
                            cum
                        ));
                    }
                    out.push_str(&format!(
                        "{}_bucket{{le=\"+Inf\"}} {}\n",
                        e.name,
                        h.count()
                    ));
                    out.push_str(&format!("{}_sum {}\n", e.name, h.sum()));
                    out.push_str(&format!("{}_count {}\n", e.name, h.count()));
                    // Interpolated quantile summary lines (untyped
                    // samples — legal exposition, and greppable without
                    // reconstructing the cumulative buckets).
                    for (tag, q) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
                        out.push_str(&format!("{}_{} {}\n", e.name, tag, h.quantile(q)));
                    }
                }
            }
        }
        out
    }
}

/// The process-global registry every runtime layer records into.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// Every well-known metric of the runtime layers, pre-registered on the
/// global [`registry`] — hot paths hold this struct once and record
/// through static derefs (no name lookup, no lock).
pub struct HotMetrics {
    // ---- transport / elastic exchange ------------------------------------
    /// Measured ring-round completion time (the controller's RTT
    /// observable), µs.
    pub rtt_us: &'static Histogram,
    /// Elastic round wall time, recoveries included, µs.
    pub round_us: &'static Histogram,
    /// Wall time of rounds that needed ≥ 1 membership recovery, µs — the
    /// cost of an epoch bump end to end.
    pub recovery_us: &'static Histogram,
    /// Enveloped frame sizes pushed into the ring, bytes.
    pub frame_bytes: &'static Histogram,
    /// Completed elastic rounds.
    pub rounds_total: &'static Counter,
    /// Payload bytes pushed into the ring (envelopes + aborted attempts
    /// included).
    pub bytes_sent_total: &'static Counter,
    /// Membership recoveries (epoch bumps) performed.
    pub recoveries_total: &'static Counter,
    /// Rounds that lost something (deadline abort / recovery) — the
    /// controller's backoff trigger.
    pub lost_rounds_total: &'static Counter,
    /// Well-formed frames discarded by epoch/step fencing.
    pub dropped_stale_total: &'static Counter,
    /// Frames rejected by envelope parse (torn writes, line noise).
    pub dropped_garbage_total: &'static Counter,
    // ---- compress --------------------------------------------------------
    /// Fused compress sweep (compensate→prune→top-k→quantize→COO→frame), ns.
    pub compress_ns: &'static Histogram,
    /// Fused decode-reduce sweep (parse→validate→dequantize→scatter), ns.
    pub decode_ns: &'static Histogram,
    /// Raw COO payload bytes offered to the lossless stage (its input —
    /// counted whether the stage wins or ships raw).
    pub lossless_raw_bytes_total: &'static Counter,
    /// Payload bytes actually shipped after lossless negotiation (wins
    /// ship the packed candidate, losses ship raw); together with
    /// `lossless_raw_bytes_total` this is the stage's net wire reduction.
    pub lossless_wire_bytes_total: &'static Counter,
    /// Buckets whose lossless candidate lost to raw COO (incompressible —
    /// the decision journal's "stage skipped" signal).
    pub lossless_skipped_total: &'static Counter,
    /// Per-bucket shipped-vs-raw byte ratio when the stage runs, percent.
    pub lossless_ratio_pct: &'static Histogram,
    // ---- sensing / controller --------------------------------------------
    /// Multiplicative-backoff transitions (Algorithm 1 line 16).
    pub ctl_backoffs_total: &'static Counter,
    /// Additive-increase transitions (startup ramp + β₂ climbs).
    pub ctl_increases_total: &'static Counter,
    /// Compression ratio in force (rank 0's controller).
    pub ratio: &'static Gauge,
    // ---- membership ------------------------------------------------------
    /// Live ranks (rank 0's view).
    pub live_ranks: &'static Gauge,
    /// Membership epoch (rank 0's view).
    pub epoch: &'static Gauge,
    // ---- cluster observability plane -------------------------------------
    /// Largest estimated per-peer clock offset of the end-of-run gather
    /// ([`crate::obs::collect`]), nanoseconds, signed (NTP midpoint
    /// method; 0 until a gather runs).
    pub clock_offset_ns: &'static Gauge,
    // ---- event-loop poller -----------------------------------------------
    /// `epoll_wait` returns across every event-loop thread (wakeups from
    /// socket readiness, command eventfds, and timer deadlines combined).
    pub poller_wakeups_total: &'static Counter,
    /// Ready events delivered per `epoll_wait` return — the batching
    /// factor; a distribution stuck at 1 means the loop pays a full
    /// syscall per frame.
    pub poller_ready_events: &'static Histogram,
    /// Connections currently armed for write interest on the sampling
    /// loop (senders parked in backpressure).
    pub poller_write_queue_depth: &'static Gauge,
    // ---- chaos injection -------------------------------------------------
    /// FaultInjector kill firings.
    pub faults_kill_total: &'static Counter,
    /// FaultInjector stall firings.
    pub faults_stall_total: &'static Counter,
    /// FaultInjector link-flap firings.
    pub faults_flap_total: &'static Counter,
    /// FaultInjector duplicate-replay firings.
    pub faults_duplicate_total: &'static Counter,
    /// FaultInjector reorder firings.
    pub faults_reorder_total: &'static Counter,
    /// FaultInjector torn-write (partial-kill) firings.
    pub faults_partial_total: &'static Counter,
    // ---- coordinator / checkpoint ----------------------------------------
    /// Simulated sync rounds driven by the coordinator's SyncEngine.
    pub sim_syncs_total: &'static Counter,
    /// Checkpoint restores applied (live rejoin + SyncEngine import).
    pub checkpoint_restores_total: &'static Counter,
}

/// The hot-metrics struct (registered once, on first use).
pub fn hot() -> &'static HotMetrics {
    static HOT: OnceLock<HotMetrics> = OnceLock::new();
    HOT.get_or_init(|| {
        let r = registry();
        HotMetrics {
            rtt_us: r.histogram(
                "netsense_rtt_us",
                "measured transfer-completion time fed to the controller, microseconds",
            ),
            round_us: r.histogram(
                "netsense_round_us",
                "elastic ring-round wall time (recoveries included), microseconds",
            ),
            recovery_us: r.histogram(
                "netsense_recovery_us",
                "wall time of rounds that needed a membership recovery, microseconds",
            ),
            frame_bytes: r.histogram(
                "netsense_frame_bytes",
                "enveloped frame sizes pushed into the ring, bytes",
            ),
            rounds_total: r.counter("netsense_rounds_total", "completed elastic rounds"),
            bytes_sent_total: r.counter(
                "netsense_bytes_sent_total",
                "payload bytes pushed into the ring (envelopes and aborted attempts included)",
            ),
            recoveries_total: r.counter(
                "netsense_recoveries_total",
                "membership recoveries (epoch bumps)",
            ),
            lost_rounds_total: r.counter(
                "netsense_lost_rounds_total",
                "rounds that lost something (deadline abort or recovery)",
            ),
            dropped_stale_total: r.counter(
                "netsense_dropped_stale_total",
                "well-formed frames discarded by epoch/step fencing",
            ),
            dropped_garbage_total: r.counter(
                "netsense_dropped_garbage_total",
                "frames rejected by envelope parse (torn writes, line noise)",
            ),
            compress_ns: r.histogram(
                "netsense_compress_ns",
                "fused compress sweep duration, nanoseconds",
            ),
            decode_ns: r.histogram(
                "netsense_decode_ns",
                "fused decode-reduce sweep duration, nanoseconds",
            ),
            lossless_raw_bytes_total: r.counter(
                "netsense_lossless_raw_bytes_total",
                "raw COO payload bytes offered to the lossless stage",
            ),
            lossless_wire_bytes_total: r.counter(
                "netsense_lossless_wire_bytes_total",
                "payload bytes shipped after lossless negotiation",
            ),
            lossless_skipped_total: r.counter(
                "netsense_lossless_skipped_total",
                "buckets whose lossless candidate lost to raw COO",
            ),
            lossless_ratio_pct: r.histogram(
                "netsense_lossless_ratio_pct",
                "per-bucket shipped-vs-raw byte ratio of the lossless stage, percent",
            ),
            ctl_backoffs_total: r.counter(
                "netsense_ctl_backoffs_total",
                "controller multiplicative-backoff transitions",
            ),
            ctl_increases_total: r.counter(
                "netsense_ctl_increases_total",
                "controller additive-increase transitions",
            ),
            ratio: r.gauge("netsense_ratio", "compression ratio in force (rank 0)"),
            live_ranks: r.gauge("netsense_live_ranks", "live ranks (rank 0's view)"),
            epoch: r.gauge("netsense_epoch", "membership epoch (rank 0's view)"),
            clock_offset_ns: r.gauge(
                "netsense_clock_offset_ns",
                "largest estimated per-peer clock offset of the telemetry gather, nanoseconds",
            ),
            poller_wakeups_total: r.counter(
                "netsense_poller_wakeups_total",
                "epoll_wait returns across all event-loop threads",
            ),
            poller_ready_events: r.histogram(
                "netsense_poller_ready_events",
                "ready events delivered per epoll_wait return",
            ),
            poller_write_queue_depth: r.gauge(
                "netsense_poller_write_queue_depth",
                "connections armed for write interest (senders in backpressure)",
            ),
            faults_kill_total: r.counter("netsense_faults_kill_total", "injected kill firings"),
            faults_stall_total: r.counter("netsense_faults_stall_total", "injected stall firings"),
            faults_flap_total: r.counter(
                "netsense_faults_flap_total",
                "injected link-flap firings",
            ),
            faults_duplicate_total: r.counter(
                "netsense_faults_duplicate_total",
                "injected duplicate-replay firings",
            ),
            faults_reorder_total: r.counter(
                "netsense_faults_reorder_total",
                "injected reorder firings",
            ),
            faults_partial_total: r.counter(
                "netsense_faults_partial_total",
                "injected torn-write (partial-kill) firings",
            ),
            sim_syncs_total: r.counter(
                "netsense_sim_syncs_total",
                "simulated sync rounds driven by the coordinator",
            ),
            checkpoint_restores_total: r.counter(
                "netsense_checkpoint_restores_total",
                "checkpoint restores applied",
            ),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        assert_eq!(c.get(), 0);
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(0.125);
        assert_eq!(g.get(), 0.125);
        g.set(-3.5);
        assert_eq!(g.get(), -3.5);
    }

    /// ISSUE satellite: bucketing edge cases — zero, u64::MAX, and the
    /// power-of-two boundaries in between.
    #[test]
    fn histogram_bucket_edges() {
        // Zero gets its own bucket.
        assert_eq!(Histogram::bucket_index(0), 0);
        // 1 = 2^0 → bucket 1; bucket i covers [2^(i-1), 2^i - 1].
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(7), 3);
        assert_eq!(Histogram::bucket_index(8), 4);
        // The top bucket holds everything from 2^63 up to u64::MAX.
        assert_eq!(Histogram::bucket_index(1 << 63), 64);
        assert_eq!(Histogram::bucket_index(u64::MAX), 64);
        // Upper bounds mirror the index ranges.
        assert_eq!(Histogram::bucket_upper_bound(0), 0);
        assert_eq!(Histogram::bucket_upper_bound(1), 1);
        assert_eq!(Histogram::bucket_upper_bound(3), 7);
        assert_eq!(Histogram::bucket_upper_bound(64), u64::MAX);
        // Every value lands in the bucket whose range contains it.
        for v in [0u64, 1, 2, 3, 255, 256, 1_000_000, u64::MAX - 1, u64::MAX] {
            let i = Histogram::bucket_index(v);
            assert!(v <= Histogram::bucket_upper_bound(i), "{v} above bucket {i}");
            if i > 0 {
                assert!(
                    v > Histogram::bucket_upper_bound(i - 1),
                    "{v} belongs below bucket {i}"
                );
            }
        }
    }

    #[test]
    fn histogram_observe_and_counts() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.5), 0, "empty histogram reports 0");
        h.observe(0);
        h.observe(1);
        h.observe(100);
        h.observe(u64::MAX);
        assert_eq!(h.count(), 4);
        let counts = h.bucket_counts();
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 1);
        assert_eq!(counts[Histogram::bucket_index(100)], 1);
        assert_eq!(counts[64], 1);
        // sum wraps with u64::MAX in play; count stays exact.
        assert_eq!(counts.iter().sum::<u64>(), 4);
    }

    /// ISSUE satellite: percentile estimates are monotone in q and report
    /// bucket upper bounds that bracket the observed values.
    #[test]
    fn histogram_percentiles_are_monotone() {
        let h = Histogram::new();
        for v in [3u64, 3, 3, 40, 40, 500, 500, 500, 9_000, 1_000_000] {
            h.observe(v);
        }
        let qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];
        let ps: Vec<u64> = qs.iter().map(|&q| h.percentile(q)).collect();
        for w in ps.windows(2) {
            assert!(w[0] <= w[1], "percentiles not monotone: {ps:?}");
        }
        // The median of this set is 500 → its bucket's upper bound (511).
        assert_eq!(h.percentile(0.5), 511);
        // p100 covers the max observation.
        assert!(ps[qs.len() - 1] >= 1_000_000);
        // Out-of-range q clamps instead of panicking.
        assert_eq!(h.percentile(-1.0), h.percentile(0.0));
        assert_eq!(h.percentile(2.0), h.percentile(1.0));
    }

    /// Pins the interpolated quantile estimator on hand-computable
    /// distributions: all mass in one bucket interpolates linearly
    /// across that bucket's `[2^(i−1), 2^i − 1]` range by rank.
    #[test]
    fn histogram_quantiles_interpolate_within_buckets() {
        // Empty histogram: defined, zero.
        let empty = Histogram::new();
        assert_eq!(empty.quantile(0.5), 0.0);

        // 100 observations, all in bucket 9 = [256, 511]. The q-th rank
        // sits at fraction q through the bucket: lo + q·(hi − lo).
        let h = Histogram::new();
        for _ in 0..100 {
            h.observe(300);
        }
        assert!((h.quantile(0.5) - 383.5).abs() < 1e-9, "{}", h.quantile(0.5));
        assert!((h.quantile(0.95) - (256.0 + 0.95 * 255.0)).abs() < 1e-9);
        assert!((h.quantile(0.99) - (256.0 + 0.99 * 255.0)).abs() < 1e-9);
        // Clamping mirrors percentile().
        assert_eq!(h.quantile(-3.0), h.quantile(0.0));
        assert_eq!(h.quantile(7.0), h.quantile(1.0));
        assert!((h.quantile(1.0) - 511.0).abs() < 1e-9);

        // Split mass: 50 zeros + 50 in [256, 511]. The lower half lands
        // in the zero bucket, the upper half interpolates as before.
        let s = Histogram::new();
        for _ in 0..50 {
            s.observe(0);
        }
        for _ in 0..50 {
            s.observe(300);
        }
        assert_eq!(s.quantile(0.25), 0.0);
        assert!((s.quantile(0.75) - 383.5).abs() < 1e-9, "{}", s.quantile(0.75));
    }

    /// The interpolated quantile is monotone in q and never exceeds the
    /// conservative bucket-upper-bound percentile at the same q.
    #[test]
    fn histogram_quantiles_are_monotone_and_bounded_by_percentiles() {
        let h = Histogram::new();
        for v in [3u64, 3, 3, 40, 40, 500, 500, 500, 9_000, 1_000_000] {
            h.observe(v);
        }
        let qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];
        let vs: Vec<f64> = qs.iter().map(|&q| h.quantile(q)).collect();
        for w in vs.windows(2) {
            assert!(w[0] <= w[1], "quantiles not monotone: {vs:?}");
        }
        for (&q, &v) in qs.iter().zip(&vs) {
            assert!(
                v <= h.percentile(q) as f64,
                "quantile({q}) = {v} exceeds percentile upper bound {}",
                h.percentile(q)
            );
        }
    }

    #[test]
    fn registry_registers_and_dedupes() {
        let r = Registry::new();
        let a = r.counter("t_a", "a");
        let b = r.counter("t_a", "a again");
        assert!(std::ptr::eq(a, b), "same name must return the same metric");
        a.inc();
        assert_eq!(b.get(), 1);
        let g = r.gauge("t_g", "g");
        g.set(2.5);
        let h = r.histogram("t_h", "h");
        h.observe(9);
        let snap = r.prometheus();
        assert!(snap.contains("# TYPE t_a counter"), "{snap}");
        assert!(snap.contains("t_a 1\n"), "{snap}");
        assert!(snap.contains("t_g 2.5\n"), "{snap}");
        assert!(snap.contains("# TYPE t_h histogram"), "{snap}");
        assert!(snap.contains("t_h_bucket{le=\"15\"} 1"), "{snap}");
        assert!(snap.contains("t_h_bucket{le=\"+Inf\"} 1"), "{snap}");
        assert!(snap.contains("t_h_sum 9"), "{snap}");
        assert!(snap.contains("t_h_count 1"), "{snap}");
    }

    #[test]
    fn prometheus_buckets_are_cumulative() {
        let r = Registry::new();
        let h = r.histogram("t_cum", "cumulative check");
        h.observe(1); // bucket 1 (le 1)
        h.observe(2); // bucket 2 (le 3)
        h.observe(3); // bucket 2
        let snap = r.prometheus();
        assert!(snap.contains("t_cum_bucket{le=\"1\"} 1"), "{snap}");
        assert!(snap.contains("t_cum_bucket{le=\"3\"} 3"), "{snap}");
        assert!(snap.contains("t_cum_bucket{le=\"+Inf\"} 3"), "{snap}");
    }

    /// Each exported histogram carries interpolated `_p50`/`_p95`/`_p99`
    /// summary lines so quantiles are greppable from the scrape without
    /// reconstructing the cumulative buckets.
    #[test]
    fn prometheus_histograms_carry_quantile_summary_lines() {
        let r = Registry::new();
        let h = r.histogram("t_qs", "quantile summary check");
        for _ in 0..100 {
            h.observe(300); // bucket [256, 511]
        }
        let snap = r.prometheus();
        assert!(snap.contains("t_qs_p50 383.5"), "{snap}");
        assert!(snap.contains("t_qs_p95 "), "{snap}");
        assert!(snap.contains("t_qs_p99 "), "{snap}");
        // Summary lines come after _count, inside the same family block.
        let count_at = snap.find("t_qs_count").expect("count line");
        let p50_at = snap.find("t_qs_p50").expect("p50 line");
        assert!(p50_at > count_at, "{snap}");
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn registry_panics_on_kind_mismatch() {
        let r = Registry::new();
        r.counter("t_kind", "as counter");
        r.gauge("t_kind", "as gauge");
    }

    #[test]
    fn hot_metrics_register_once_on_the_global_registry() {
        let m1 = hot();
        let m2 = hot();
        assert!(std::ptr::eq(m1, m2));
        // Recording through hot() lands in the global snapshot. (Other
        // tests share the process registry — assert on deltas only.)
        let before = m1.rounds_total.get();
        m1.rounds_total.inc();
        assert!(m2.rounds_total.get() >= before + 1);
        assert!(registry().prometheus().contains("netsense_rounds_total"));
    }
}
