//! Critical-path attribution over a merged, clock-aligned timeline
//! (DESIGN.md §3.12) — the analysis pass behind `--analysis-out`.
//!
//! The paper's thesis is that compression should be applied *only when
//! congestion actually hurts*: answering "was it helping at step N?"
//! needs to know, per step, where the wall time went — compute, codec,
//! wire, or recovery — and which rank's slowness actually stalled each
//! round. [`analyze`] derives all of that from nothing but the merged
//! span rings ([`crate::obs::align::merge_aligned`] output) and rank 0's
//! decision journal:
//!
//! - **per-step breakdown** from rank 0's span tree (`step ⊃ compress,
//!   round ⊃ decode×n`): `compress` and `decode` are their spans' sums,
//!   `wire = round − Σdecode`, `compute = step − compress − round`
//!   (saturating), so the parts sum to the step wall time *exactly*. A
//!   step that ran a recovery reports its round remainder as `recovery`
//!   instead of `wire` — inside the round span the two are
//!   indistinguishable, and misattributing a recovery storm as wire time
//!   would fake a congestion signal.
//! - **straggler attribution**: per round, the critical-path rank is the
//!   one whose `round` span ran longest (everyone else finished the
//!   exchange waiting for it); a count-by-rank table plus a verdict when
//!   one rank owns ≥ half of all rounds.
//! - **compression efficacy**: the journal's ratio decisions joined with
//!   step wall times — predicted wire bytes vs the dense baseline vs
//!   what the step actually cost.
//!
//! Verdicts are also emitted as [`DecisionKind::Straggler`] /
//! [`DecisionKind::Congestion`] journal records
//! ([`Analysis::verdict_records`]) so downstream consumers see them in
//! the same stream as the controller's own decisions. Everything here is
//! dependency-free and runs strictly after training — never on the fused
//! hot path.

use crate::obs::journal::{DecisionKind, DecisionRecord};
use crate::obs::trace::SpanRecord;
use crate::util::json::{obj, Json};

/// Where one step's wall time went, in nanoseconds. Invariant:
/// `compute + compress + wire + decode + recovery == wall` exactly
/// (the analyzer derives `wire` and `compute` by subtraction).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepBreakdown {
    pub step: u32,
    pub wall_ns: u64,
    pub compute_ns: u64,
    pub compress_ns: u64,
    pub wire_ns: u64,
    pub decode_ns: u64,
    pub recovery_ns: u64,
    /// The rank whose `round` span ran longest this step (`None` when no
    /// rank recorded a round — e.g. tracing disabled on peers).
    pub critical_rank: Option<usize>,
}

/// One point of the compression-efficacy series: a ratio decision joined
/// with the step it acted on.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EfficacyPoint {
    pub step: u32,
    pub ratio: f64,
    pub predicted_wire_bytes: u64,
    /// Dense baseline minus predicted wire bytes (saturating) — what the
    /// current ratio saved on the wire this interval.
    pub bytes_saved: u64,
    pub wall_ns: u64,
}

/// The machine-readable product of [`analyze`] — serialized to
/// `ANALYSIS.json` by the live CLI (`--analysis-out`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Analysis {
    pub n_ranks: usize,
    pub steps: Vec<StepBreakdown>,
    /// `straggler_counts[r]` = number of rounds rank `r` was the
    /// critical path of. Sums to the number of steps with a verdict.
    pub straggler_counts: Vec<u64>,
    /// A rank that owned ≥ half of all attributed rounds (multi-rank
    /// runs only — a solo run has no one to straggle behind).
    pub straggler_verdict: Option<usize>,
    /// True when the journal shows at least one loss-driven backoff —
    /// the controller itself sensed congestion during the run.
    pub congestion_verdict: bool,
    pub efficacy: Vec<EfficacyPoint>,
}

/// Run the attribution pass. `spans` is the merged (clock-aligned)
/// timeline, `journal` rank 0's decision journal, `dense_bytes` the
/// uncompressed gradient size (`n_params × 4`) anchoring the efficacy
/// series.
pub fn analyze(
    spans: &[SpanRecord],
    journal: &[DecisionRecord],
    n_ranks: usize,
    dense_bytes: u64,
) -> Analysis {
    // Steps in rank 0's track order; per-step rollups off the span tree.
    let mut steps: Vec<StepBreakdown> = Vec::new();
    for s in spans.iter().filter(|s| s.rank == 0 && s.label == "step") {
        steps.push(StepBreakdown {
            step: s.step,
            wall_ns: s.end_ns - s.start_ns,
            ..StepBreakdown::default()
        });
    }
    steps.sort_by_key(|b| b.step);
    steps.dedup_by_key(|b| b.step); // ring wrap can re-record a step id

    let mut counts = vec![0u64; n_ranks];
    for b in &mut steps {
        let mut round_ns = 0u64;
        let mut had_recovery = false;
        for s in spans.iter().filter(|s| s.step == b.step) {
            match (s.rank, s.label) {
                (0, "compress") => b.compress_ns += s.end_ns - s.start_ns,
                (0, "round") => round_ns += s.end_ns - s.start_ns,
                (0, "decode") => b.decode_ns += s.end_ns - s.start_ns,
                (0, "recovery") => had_recovery = true,
                _ => {}
            }
        }
        // Critical path: the rank whose exchange ran longest this round.
        let mut worst: Option<(u64, usize)> = None;
        for s in spans.iter().filter(|s| s.step == b.step && s.label == "round") {
            let d = s.end_ns - s.start_ns;
            let better = match worst {
                None => true,
                Some((wd, wr)) => d > wd || (d == wd && s.rank < wr),
            };
            if better {
                worst = Some((d, s.rank));
            }
        }
        if let Some((_, r)) = worst {
            b.critical_rank = Some(r);
            if let Some(c) = counts.get_mut(r) {
                *c += 1;
            }
        }
        let remainder = round_ns.saturating_sub(b.decode_ns);
        if had_recovery {
            b.recovery_ns = remainder;
        } else {
            b.wire_ns = remainder;
        }
        b.compute_ns = b.wall_ns.saturating_sub(b.compress_ns).saturating_sub(round_ns);
    }

    let attributed: u64 = counts.iter().sum();
    let straggler_verdict = counts
        .iter()
        .enumerate()
        .max_by_key(|(_, c)| **c)
        .filter(|(_, c)| n_ranks > 1 && attributed > 0 && **c * 2 >= attributed)
        .map(|(r, _)| r);

    let congestion_verdict = journal
        .iter()
        .any(|r| r.kind == DecisionKind::Ratio && r.lost);

    let efficacy = journal
        .iter()
        .filter(|r| r.kind == DecisionKind::Ratio)
        .map(|r| EfficacyPoint {
            step: r.step,
            ratio: r.new_ratio,
            predicted_wire_bytes: r.predicted_wire_bytes,
            bytes_saved: dense_bytes.saturating_sub(r.predicted_wire_bytes),
            wall_ns: steps
                .iter()
                .find(|b| b.step == r.step)
                .map_or(0, |b| b.wall_ns),
        })
        .collect();

    Analysis {
        n_ranks,
        steps,
        straggler_counts: counts,
        straggler_verdict,
        congestion_verdict,
        efficacy,
    }
}

impl Analysis {
    /// `ANALYSIS.json` (pretty-printed, `schema_version` 1 — the schema
    /// `scripts/check_trace.py` validates).
    pub fn to_json(&self) -> String {
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|b| {
                obj(vec![
                    ("step", Json::from(b.step as usize)),
                    ("wall_ns", Json::from(b.wall_ns)),
                    ("compute_ns", Json::from(b.compute_ns)),
                    ("compress_ns", Json::from(b.compress_ns)),
                    ("wire_ns", Json::from(b.wire_ns)),
                    ("decode_ns", Json::from(b.decode_ns)),
                    ("recovery_ns", Json::from(b.recovery_ns)),
                    (
                        "critical_rank",
                        b.critical_rank.map_or(Json::Null, Json::from),
                    ),
                ])
            })
            .collect();
        let efficacy: Vec<Json> = self
            .efficacy
            .iter()
            .map(|p| {
                obj(vec![
                    ("step", Json::from(p.step as usize)),
                    ("ratio", Json::from(p.ratio)),
                    ("predicted_wire_bytes", Json::from(p.predicted_wire_bytes)),
                    ("bytes_saved", Json::from(p.bytes_saved)),
                    ("wall_ns", Json::from(p.wall_ns)),
                ])
            })
            .collect();
        obj(vec![
            ("schema_version", Json::from(1usize)),
            ("n_ranks", Json::from(self.n_ranks)),
            ("steps", Json::Arr(steps)),
            (
                "straggler_counts",
                Json::Arr(self.straggler_counts.iter().map(|c| Json::from(*c)).collect()),
            ),
            (
                "straggler_verdict",
                self.straggler_verdict.map_or(Json::Null, Json::from),
            ),
            ("congestion_verdict", Json::from(self.congestion_verdict)),
            ("efficacy", Json::Arr(efficacy)),
        ])
        .to_string_pretty()
    }

    /// The verdicts as journal records, appended to the run's journal so
    /// downstream consumers see them in the controller's own stream.
    /// Field reuse (flat `Copy` record, no payload variants): a
    /// `Straggler` record carries the straggling rank in `rank`, its
    /// round count in `payload_bytes`, and the attributed total in
    /// `rtt_us`; a `Congestion` record sets `lost` and carries the
    /// backoff count in `payload_bytes`.
    pub fn verdict_records(&self, journal: &[DecisionRecord]) -> Vec<DecisionRecord> {
        let mut out = Vec::new();
        if let Some(r) = self.straggler_verdict {
            out.push(DecisionRecord {
                kind: DecisionKind::Straggler,
                rank: r,
                live: self.n_ranks,
                payload_bytes: self.straggler_counts.get(r).copied().unwrap_or(0),
                rtt_us: self.straggler_counts.iter().sum(),
                ..DecisionRecord::default()
            });
        }
        if self.congestion_verdict {
            let backoffs = journal
                .iter()
                .filter(|r| r.kind == DecisionKind::Ratio && r.lost)
                .count() as u64;
            out.push(DecisionRecord {
                kind: DecisionKind::Congestion,
                live: self.n_ranks,
                lost: true,
                payload_bytes: backoffs,
                ..DecisionRecord::default()
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(rank: usize, id: u64, label: &'static str, step: u32, start: u64, end: u64) -> SpanRecord {
        SpanRecord {
            rank,
            id,
            parent: 0,
            label,
            step,
            start_ns: start,
            end_ns: end,
        }
    }

    /// Two ranks, two steps with hand-built trees; every attribution
    /// value is pinned and the parts sum to the wall exactly.
    #[test]
    fn obs_analyze_pins_the_per_step_breakdown() {
        let spans = vec![
            // step 0: wall 10_000, compress 2_000, round 5_000 with two
            // decodes of 1_000 → wire 3_000, compute 3_000.
            span(0, 1, "step", 0, 0, 10_000),
            span(0, 2, "compress", 0, 500, 2_500),
            span(0, 3, "round", 0, 3_000, 8_000),
            span(0, 4, "decode", 0, 3_500, 4_500),
            span(0, 5, "decode", 0, 5_000, 6_000),
            span(1, 1, "round", 0, 3_000, 9_000), // rank 1 straggles
            // step 1: recovery — round remainder becomes recovery_ns.
            span(0, 6, "step", 1, 10_000, 30_000),
            span(0, 7, "compress", 1, 10_500, 12_500),
            span(0, 8, "round", 1, 13_000, 28_000),
            span(0, 9, "decode", 1, 14_000, 15_000),
            span(0, 10, "recovery", 1, 28_000, 28_000),
            span(1, 2, "round", 1, 13_000, 29_000), // rank 1 straggles again
        ];
        let a = analyze(&spans, &[], 2, 0);
        assert_eq!(a.steps.len(), 2);

        let s0 = a.steps[0];
        assert_eq!(
            (s0.wall_ns, s0.compute_ns, s0.compress_ns, s0.wire_ns, s0.decode_ns, s0.recovery_ns),
            (10_000, 3_000, 2_000, 3_000, 2_000, 0)
        );
        assert_eq!(s0.critical_rank, Some(1));

        let s1 = a.steps[1];
        assert_eq!(
            (s1.wall_ns, s1.compute_ns, s1.compress_ns, s1.wire_ns, s1.decode_ns, s1.recovery_ns),
            (20_000, 3_000, 2_000, 0, 1_000, 14_000)
        );
        assert_eq!(s1.critical_rank, Some(1));

        for s in &a.steps {
            assert_eq!(
                s.compute_ns + s.compress_ns + s.wire_ns + s.decode_ns + s.recovery_ns,
                s.wall_ns,
                "attribution must sum to the wall exactly (step {})",
                s.step
            );
        }

        assert_eq!(a.straggler_counts, vec![0, 2]);
        assert_eq!(a.straggler_verdict, Some(1));
        assert!(!a.congestion_verdict);
    }

    #[test]
    fn obs_analyze_requires_a_majority_for_the_straggler_verdict() {
        // Three steps, critical rank alternates 0, 1, 2 — nobody owns half.
        let mut spans = Vec::new();
        for step in 0..3u32 {
            let base = step as u64 * 10_000;
            spans.push(span(0, 10 + step as u64, "step", step, base, base + 9_000));
            for rank in 0..3usize {
                let d = if rank == step as usize % 3 { 5_000 } else { 2_000 };
                spans.push(span(rank, 20 + step as u64, "round", step, base + 1_000, base + 1_000 + d));
            }
        }
        let a = analyze(&spans, &[], 3, 0);
        assert_eq!(a.straggler_counts, vec![1, 1, 1]);
        assert_eq!(a.straggler_verdict, None);
        // And a solo run never has a straggler, even at 100% share.
        let solo = vec![
            span(0, 1, "step", 0, 0, 1_000),
            span(0, 2, "round", 0, 100, 900),
        ];
        assert_eq!(analyze(&solo, &[], 1, 0).straggler_verdict, None);
    }

    #[test]
    fn obs_analyze_joins_efficacy_and_flags_congestion() {
        let spans = vec![
            span(0, 1, "step", 3, 0, 7_000),
            span(0, 2, "round", 3, 1_000, 3_000),
        ];
        let journal = vec![
            DecisionRecord {
                kind: DecisionKind::Ratio,
                step: 3,
                new_ratio: 0.25,
                predicted_wire_bytes: 1_000,
                lost: true,
                ..DecisionRecord::default()
            },
            DecisionRecord {
                kind: DecisionKind::Round,
                step: 3,
                ..DecisionRecord::default()
            },
        ];
        let a = analyze(&spans, &journal, 1, 4_000);
        assert!(a.congestion_verdict);
        assert_eq!(a.efficacy.len(), 1, "only Ratio records join the series");
        let p = a.efficacy[0];
        assert_eq!(
            (p.step, p.ratio, p.predicted_wire_bytes, p.bytes_saved, p.wall_ns),
            (3, 0.25, 1_000, 3_000, 7_000)
        );

        let verdicts = a.verdict_records(&journal);
        assert_eq!(verdicts.len(), 1); // congestion only (solo run)
        assert_eq!(verdicts[0].kind, DecisionKind::Congestion);
        assert!(verdicts[0].lost);
        assert_eq!(verdicts[0].payload_bytes, 1);
    }

    #[test]
    fn obs_analysis_json_has_the_documented_schema() {
        let spans = vec![
            span(0, 1, "step", 0, 0, 5_000),
            span(0, 2, "round", 0, 1_000, 3_000),
            span(1, 3, "round", 0, 1_000, 4_000),
        ];
        let a = analyze(&spans, &[], 2, 0);
        let doc = crate::util::json::Json::parse(&a.to_json()).expect("ANALYSIS.json must parse");
        assert_eq!(doc.get("schema_version").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(doc.get("n_ranks").and_then(|v| v.as_usize()), Some(2));
        let steps = doc.get("steps").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(steps.len(), 1);
        for key in ["step", "wall_ns", "compute_ns", "compress_ns", "wire_ns", "decode_ns", "recovery_ns"] {
            assert!(steps[0].get(key).and_then(|v| v.as_f64()).is_some(), "missing {key}");
        }
        assert_eq!(steps[0].get("critical_rank").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(
            doc.get("straggler_counts").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(2)
        );
        assert_eq!(doc.get("straggler_verdict").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(doc.get("congestion_verdict").and_then(|v| v.as_bool()), Some(false));
        assert!(doc.get("efficacy").and_then(|v| v.as_arr()).is_some());
    }
}
