//! The controller decision journal: a flat, preallocated record of what
//! the adaptive loop *decided* — every [`RatioController`] transition
//! (observed RTT/loss, phase, old → new ratio, predicted wire bytes) and
//! every round/membership event — dumped as JSON per run.
//!
//! Records are `Copy` and live in a bounded `Vec` allocated up front:
//! pushing in steady state is a slot write (gated by the zero-alloc test
//! in the parent module), and unlike the span ring the journal does NOT
//! wrap — decisions are the ground truth a replay is checked against, so
//! dropping the *oldest* would be worse than dropping the newest. Past
//! capacity, pushes tick a drop counter and the journal says so.
//!
//! Cross-checks this enables (asserted in `experiments::live` tests):
//! the `Ratio` records' `old_ratio`/`new_ratio` chain must match the
//! run's per-step trace, and the `Round` records' `(epoch, live)`
//! sequence must equal the run's
//! [`SyncTrajectory`](crate::fault::SyncTrajectory) — i.e. the journal
//! is the same story netsim replays tell.
//!
//! [`RatioController`]: crate::sensing::RatioController

use crate::util::json::{obj, Json};

/// What a [`DecisionRecord`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecisionKind {
    /// A [`RatioController`](crate::sensing::RatioController) transition.
    #[default]
    Ratio,
    /// A completed elastic round (`RoundStats` digest).
    Round,
    /// A membership change (epoch bump / live-set shrink).
    Membership,
    /// An analyzer verdict: one rank was the critical path of ≥ half the
    /// rounds ([`crate::obs::analyze`] — `rank` holds the straggler).
    Straggler,
    /// An analyzer verdict: the run saw loss-driven backoff (the
    /// controller itself sensed congestion).
    Congestion,
}

impl DecisionKind {
    pub fn as_str(self) -> &'static str {
        match self {
            DecisionKind::Ratio => "ratio",
            DecisionKind::Round => "round",
            DecisionKind::Membership => "membership",
            DecisionKind::Straggler => "straggler",
            DecisionKind::Congestion => "congestion",
        }
    }
}

/// One journal entry. Flat and `Copy`; unused fields stay at their
/// `Default` for the record's kind (construct with
/// `..DecisionRecord::default()`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DecisionRecord {
    pub kind: DecisionKind,
    /// Worker rank that recorded the entry.
    pub rank: usize,
    /// Training step the entry belongs to.
    pub step: u32,
    /// Membership epoch in force.
    pub epoch: u32,
    /// Live ranks in force.
    pub live: usize,
    /// Observed transfer-completion time, µs (Ratio/Round).
    pub rtt_us: u64,
    /// Payload bytes the observation covered (Ratio: `data_size`;
    /// Round: `sent_bytes`).
    pub payload_bytes: u64,
    /// Whether the interval/round lost something.
    pub lost: bool,
    /// Controller phase after the transition (Ratio only):
    /// `false` = Startup, `true` = NetSense.
    pub phase_netsense: bool,
    /// Compression ratio before the transition (Ratio only).
    pub old_ratio: f64,
    /// Compression ratio after the transition (Ratio only).
    pub new_ratio: f64,
    /// Wire bytes the compressor predicts at `new_ratio` (Ratio only).
    pub predicted_wire_bytes: u64,
    /// Recoveries performed in the round (Round/Membership).
    pub recoveries: u32,
    /// Stale frames fenced in the round (Round only).
    pub dropped_stale: u32,
    /// Garbage frames rejected in the round (Round only).
    pub dropped_garbage: u32,
}

impl DecisionRecord {
    /// Serialize one record as a JSON object (kind-irrelevant fields
    /// included — flat schema, trivially diffable).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::from(self.kind.as_str())),
            ("rank", Json::from(self.rank)),
            ("step", Json::from(self.step as usize)),
            ("epoch", Json::from(self.epoch as usize)),
            ("live", Json::from(self.live)),
            ("rtt_us", Json::from(self.rtt_us)),
            ("payload_bytes", Json::from(self.payload_bytes)),
            ("lost", Json::from(self.lost)),
            ("phase_netsense", Json::from(self.phase_netsense)),
            ("old_ratio", Json::from(self.old_ratio)),
            ("new_ratio", Json::from(self.new_ratio)),
            ("predicted_wire_bytes", Json::from(self.predicted_wire_bytes)),
            ("recoveries", Json::from(self.recoveries as usize)),
            ("dropped_stale", Json::from(self.dropped_stale as usize)),
            ("dropped_garbage", Json::from(self.dropped_garbage as usize)),
        ])
    }
}

/// Bounded, preallocated journal of [`DecisionRecord`]s. See module docs.
pub struct DecisionJournal {
    records: Vec<DecisionRecord>,
    enabled: bool,
    dropped: u64,
}

impl DecisionJournal {
    /// A journal holding up to `capacity` records, all storage allocated
    /// here. Size generously: one live run produces roughly
    /// `steps × (1 ratio + 1 round)` records on the journaling rank.
    pub fn with_capacity(capacity: usize) -> DecisionJournal {
        DecisionJournal {
            records: Vec::with_capacity(capacity),
            enabled: capacity > 0,
            dropped: 0,
        }
    }

    /// A journal whose `push` is a no-op — the disabled default, so call
    /// sites don't branch.
    pub fn disabled() -> DecisionJournal {
        DecisionJournal {
            records: Vec::new(),
            enabled: false,
            dropped: 0,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Append a record. Past capacity, ticks the drop counter instead of
    /// growing (keeps the hot path allocation-free).
    #[inline]
    pub fn push(&mut self, rec: DecisionRecord) {
        if !self.enabled {
            return;
        }
        if self.records.len() < self.records.capacity() {
            self.records.push(rec);
        } else {
            self.dropped += 1;
        }
    }

    pub fn records(&self) -> &[DecisionRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records refused because the journal was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The `(epoch, live)` sequence of the `Round` records — directly
    /// comparable to a run's
    /// [`SyncTrajectory`](crate::fault::SyncTrajectory) without importing
    /// `fault` here.
    pub fn epoch_trajectory(&self) -> Vec<(u32, usize)> {
        epoch_trajectory_of(&self.records)
    }

    /// Serialize the whole journal (records + drop accounting) as a JSON
    /// document. Cold path.
    pub fn to_json(&self) -> String {
        records_to_json(&self.records, self.dropped)
    }
}

/// [`DecisionJournal::epoch_trajectory`] over a bare record slice (for
/// callers that hold the records without the journal, e.g. a merged run
/// report).
pub fn epoch_trajectory_of(records: &[DecisionRecord]) -> Vec<(u32, usize)> {
    let mut out = Vec::new();
    for r in records {
        if r.kind != DecisionKind::Round {
            continue;
        }
        if out.last() != Some(&(r.epoch, r.live)) {
            out.push((r.epoch, r.live));
        }
    }
    out
}

/// [`DecisionJournal::to_json`] over a bare record slice.
pub fn records_to_json(records: &[DecisionRecord], dropped: u64) -> String {
    let records: Vec<Json> = records.iter().map(|r| r.to_json()).collect();
    obj(vec![
        ("schema_version", Json::from(1usize)),
        ("dropped", Json::from(dropped)),
        ("records", Json::Arr(records)),
    ])
    .to_string_pretty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_is_bounded_and_counts_drops() {
        let mut j = DecisionJournal::with_capacity(2);
        assert!(j.is_enabled() && j.is_empty());
        for step in 0..5u32 {
            j.push(DecisionRecord {
                step,
                ..DecisionRecord::default()
            });
        }
        assert_eq!(j.len(), 2);
        assert_eq!(j.dropped(), 3);
        // Oldest records survive — they're what replays are checked against.
        assert_eq!(j.records()[0].step, 0);
        assert_eq!(j.records()[1].step, 1);
    }

    #[test]
    fn disabled_journal_records_nothing() {
        let mut j = DecisionJournal::disabled();
        j.push(DecisionRecord::default());
        assert!(j.is_empty());
        assert_eq!(j.dropped(), 0);
        assert!(!j.is_enabled());
    }

    #[test]
    fn epoch_trajectory_dedupes_consecutive_rounds() {
        let mut j = DecisionJournal::with_capacity(8);
        for (step, (epoch, live)) in [(0u32, (0u32, 4usize)), (1, (0, 4)), (2, (1, 3)), (3, (1, 3))]
        {
            j.push(DecisionRecord {
                kind: DecisionKind::Round,
                step,
                epoch,
                live,
                ..DecisionRecord::default()
            });
        }
        // A Ratio record with a different epoch must not leak in.
        j.push(DecisionRecord {
            kind: DecisionKind::Ratio,
            epoch: 9,
            live: 9,
            ..DecisionRecord::default()
        });
        assert_eq!(j.epoch_trajectory(), vec![(0, 4), (1, 3)]);
    }

    #[test]
    fn journal_json_round_trips_through_the_parser() {
        let mut j = DecisionJournal::with_capacity(4);
        j.push(DecisionRecord {
            kind: DecisionKind::Ratio,
            rank: 0,
            step: 3,
            epoch: 1,
            live: 4,
            rtt_us: 250,
            payload_bytes: 8192,
            lost: true,
            phase_netsense: true,
            old_ratio: 0.25,
            new_ratio: 0.125,
            predicted_wire_bytes: 4096,
            ..DecisionRecord::default()
        });
        j.push(DecisionRecord {
            kind: DecisionKind::Membership,
            epoch: 2,
            live: 3,
            recoveries: 1,
            ..DecisionRecord::default()
        });
        let doc = Json::parse(&j.to_json()).expect("journal JSON parses");
        assert_eq!(doc.get("dropped").and_then(|v| v.as_f64()), Some(0.0));
        let records = doc.get("records").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(records.len(), 2);
        let r0 = &records[0];
        assert_eq!(r0.get("kind").and_then(|v| v.as_str()), Some("ratio"));
        assert_eq!(r0.get("old_ratio").and_then(|v| v.as_f64()), Some(0.25));
        assert_eq!(r0.get("new_ratio").and_then(|v| v.as_f64()), Some(0.125));
        assert_eq!(r0.get("lost").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            r0.get("predicted_wire_bytes").and_then(|v| v.as_usize()),
            Some(4096)
        );
        assert_eq!(
            records[1].get("kind").and_then(|v| v.as_str()),
            Some("membership")
        );
    }
}
