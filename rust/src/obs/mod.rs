//! Zero-overhead runtime telemetry: what the adaptive system *decided*
//! and what each decision *cost*, observable without perturbing the
//! zero-alloc hot paths it measures.
//!
//! Three dependency-free pieces:
//!
//! - **[`metrics`]** — a global registry of named atomic counters, gauges
//!   and log₂-bucketed histograms (RTT, compress/decode ns, frame bytes,
//!   recovery latency). Hot-path recording is a single relaxed atomic
//!   add — lock-free, allocation-free — and the registry snapshots to
//!   Prometheus text exposition format ([`metrics::Registry::prometheus`])
//!   for files or the live scrape endpoint ([`serve`]).
//! - **[`trace`]** — per-rank tracing spans in a preallocated ring buffer
//!   (span id, parent, label, start/end ns, step), recorded by the live
//!   worker loop around the fused compress sweep, the elastic ring round
//!   and each decode-reduce, and exported as Chrome `trace_event` JSON
//!   ([`trace::chrome_trace_json`]) — drop the file on
//!   <https://ui.perfetto.dev> and read a multi-worker step off the
//!   timeline, one track per rank.
//! - **[`journal`]** — the controller decision journal: every
//!   [`RatioController`](crate::sensing::RatioController) transition
//!   (observed RTT/loss, phase, old → new ratio, predicted wire bytes)
//!   and every round/membership event as flat `Copy` records in a
//!   preallocated buffer, dumped as JSON per run and cross-checkable
//!   against the run's [`SyncTrajectory`](crate::fault::SyncTrajectory)
//!   and netsim replays.
//!
//! On top of the per-rank pieces sits the **cluster observability plane**
//! (DESIGN.md §3.12), three more modules that run strictly after (or on
//! abort of) the training loop:
//!
//! - **[`collect`]** — the end-of-run gather: each rank serializes its
//!   span ring + journal + counter snapshot into a versioned `NSOB`
//!   payload and ships it to rank 0 over the transport seam, preceded by
//!   a clock ping/pong per peer. Malformed payloads are named `Err`s,
//!   dead peers become notes — collection is best-effort by design.
//! - **[`align`]** — NTP-midpoint clock-offset estimation and the
//!   offset-applying merge that stitches per-rank rings into one
//!   monotonic timeline, so multi-process TCP traces align like the
//!   shared-origin loopback ones always did.
//! - **[`analyze`]** — critical-path attribution over the merged
//!   timeline: per-step compute/compress/wire/decode/recovery breakdown,
//!   per-round straggler attribution, and a compression-efficacy series,
//!   emitted as `ANALYSIS.json` plus `Straggler`/`Congestion` journal
//!   verdicts.
//!
//! §Perf contract: recording a metric, opening/closing a span, and
//! pushing a journal record are all allocation-free in steady state — the
//! counting-allocator gates in [`crate::fault::collective`] run the fused
//! send and receive paths *with telemetry on* and still assert 0
//! allocs/step, and `telemetry_recording_is_allocation_free` below gates
//! the recording primitives themselves. Registration (naming a metric)
//! allocates once, at startup; export (JSON/Prometheus strings) is cold
//! by construction.

pub mod align;
pub mod analyze;
pub mod collect;
pub mod journal;
pub mod metrics;
pub mod serve;
pub mod trace;

pub use align::{estimate_offset, merge_aligned};
pub use analyze::{analyze, Analysis, EfficacyPoint, StepBreakdown};
pub use collect::{
    decode_telemetry, encode_telemetry, gather_at_rank0, respond_to_collector, PeerCollection,
    RankTelemetry,
};
pub use journal::{DecisionJournal, DecisionKind, DecisionRecord};
pub use metrics::{hot, registry, Counter, Gauge, Histogram, HotMetrics, Registry};
pub use serve::MetricsServer;
pub use trace::{chrome_trace_json, chrome_trace_json_with_offsets, SpanId, SpanRecord, Tracer};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::alloc::thread_alloc_count;
    use std::time::Instant;

    /// The obs-layer half of the zero-alloc contract: one synthetic
    /// "step" that records everything the live worker loop records —
    /// nested spans, histogram observations, counter bumps, gauge sets,
    /// and a journal push — performs ZERO heap allocations once warm.
    #[test]
    fn telemetry_recording_is_allocation_free() {
        let origin = Instant::now();
        let mut tracer = Tracer::new(0, 256, origin);
        let mut journal = DecisionJournal::with_capacity(128);
        let m = hot();
        let mut step_no = 0u32;
        let mut step = |tracer: &mut Tracer, journal: &mut DecisionJournal, step_no: &mut u32| {
            let sp_step = tracer.start("step", *step_no);
            let sp_c = tracer.start("compress", *step_no);
            m.compress_ns.observe(1234);
            tracer.end(sp_c);
            let sp_r = tracer.start("round", *step_no);
            for _ in 0..4 {
                let sp_d = tracer.start("decode", *step_no);
                m.decode_ns.observe(567);
                tracer.end(sp_d);
            }
            m.rounds_total.inc();
            m.bytes_sent_total.add(4096);
            m.rtt_us.observe(250);
            m.round_us.observe(300);
            m.frame_bytes.observe(1024);
            m.ratio.set(0.25);
            tracer.end(sp_r);
            tracer.end(sp_step);
            journal.push(DecisionRecord {
                kind: DecisionKind::Ratio,
                step: *step_no,
                old_ratio: 0.25,
                new_ratio: 0.26,
                ..DecisionRecord::default()
            });
            *step_no += 1;
        };
        for _ in 0..40 {
            step(&mut tracer, &mut journal, &mut step_no);
        }
        let before = thread_alloc_count();
        for _ in 0..10 {
            step(&mut tracer, &mut journal, &mut step_no);
        }
        let allocs = thread_alloc_count() - before;
        assert_eq!(allocs, 0, "telemetry recording allocated {allocs} times");
    }
}
