//! Cross-rank telemetry collection: the end-of-run gather that turns
//! per-rank observability islands into one cluster-wide picture
//! (DESIGN.md §3.12).
//!
//! Each rank serializes its span ring, decision journal, and a flat
//! snapshot of its run counters into a versioned **OBS payload** (magic
//! `NSOB`), then ships it to rank 0 over the existing transport seam
//! inside [`FrameKind::Obs`](crate::fault::FrameKind) envelopes. A
//! [`FrameKind::Clock`](crate::fault::FrameKind) ping/pong precedes the
//! payload so rank 0 can estimate each peer's clock offset
//! ([`crate::obs::align::estimate_offset`], NTP midpoint method) and
//! stitch the rings onto one timeline.
//!
//! The payload obeys the PR-5/PR-6 corruption contract: a malformed blob
//! returns a named `Err`, never panics, and a lying count field cannot
//! trigger a large allocation (every count is cross-checked against the
//! bytes actually present before reserving). The whole path runs strictly
//! **after** the training loop — the fused hot path and its zero-alloc
//! gates never see it.
//!
//! ```
//! use netsenseml::obs::collect::{decode_telemetry, encode_telemetry, RankTelemetry};
//!
//! let telemetry = RankTelemetry { rank: 3, final_ratio: 0.25, ..RankTelemetry::default() };
//! let bytes = encode_telemetry(&telemetry);
//! assert_eq!(decode_telemetry(&bytes).unwrap(), telemetry);
//! assert!(decode_telemetry(&bytes[..bytes.len() - 1]).is_err()); // truncated → named Err
//! ```

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::fault::{parse_envelope, write_envelope, FrameKind, ENVELOPE_OVERHEAD};
use crate::obs::align::estimate_offset;
use crate::obs::journal::{DecisionKind, DecisionRecord};
use crate::obs::trace::SpanRecord;
use crate::transport::Transport;
use crate::util::error::{anyhow, Result};

/// Leading magic of an OBS payload.
pub const OBS_MAGIC: [u8; 4] = *b"NSOB";
/// Current payload format version (bump on any layout change).
pub const OBS_VERSION: u16 = 1;

/// Fixed-size header: magic + version + rank + clock + drop counters +
/// the flat run-counter snapshot.
const HEADER_BYTES: usize = 4 + 2 + 4 + 8 + 8 + 8 + 8 + 4 + 4 + 4 + 4;
/// Serialized size of one span record (label index replaces the label).
const SPAN_BYTES: usize = 2 + 8 + 8 + 4 + 8 + 8;
/// Serialized size of one journal record.
const JOURNAL_BYTES: usize = 1 + 4 + 4 + 4 + 4 + 8 + 8 + 1 + 8 + 8 + 8 + 4 + 4 + 4;

/// Decode-side caps: a lying header names a defect instead of an
/// allocation. Counts are *additionally* checked against remaining bytes.
const MAX_LABELS: usize = 1024;
const MAX_LABEL_LEN: usize = 256;
const MAX_RECORDS: usize = 1 << 22;

/// Span labels are `&'static str` by contract ([`SpanRecord`]); decoding
/// foreign labels re-uses the well-known set and leak-interns the rest,
/// capped so hostile payloads cannot grow the intern table unboundedly.
const KNOWN_LABELS: &[&str] = &["step", "compress", "round", "decode", "recovery"];
const MAX_INTERNED_LABELS: usize = 64;
static INTERNED: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());

/// Bounded skip budget while waiting for a specific envelope kind: stray
/// duplicated / reordered frames from a chaotic last round are discarded,
/// but a babbling peer cannot pin the collector forever.
const MAX_SKIPPED_FRAMES: usize = 64;

fn intern_label(s: &str) -> Result<&'static str> {
    if let Some(k) = KNOWN_LABELS.iter().find(|k| **k == s) {
        return Ok(k);
    }
    let mut table = INTERNED.lock().unwrap();
    if let Some(k) = table.iter().find(|k| **k == s) {
        return Ok(k);
    }
    if table.len() >= MAX_INTERNED_LABELS {
        return Err(anyhow!("too many distinct span labels"));
    }
    let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
    table.push(leaked);
    Ok(leaked)
}

/// Everything one rank contributes to the cluster picture: its span
/// ring, its decision journal, and a flat snapshot of the counters the
/// live report aggregates. `clock_ns` is the rank's origin-relative time
/// at snapshot — a sanity anchor, not the offset source (that is the
/// Clock ping/pong).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankTelemetry {
    pub rank: usize,
    pub clock_ns: u64,
    pub spans: Vec<SpanRecord>,
    pub spans_dropped: u64,
    pub journal: Vec<DecisionRecord>,
    pub journal_dropped: u64,
    pub final_ratio: f64,
    pub recoveries: u32,
    pub lost_intervals: u32,
    pub decreases: u32,
    pub increases: u32,
}

/// What a rank-0 gather produced: per-peer telemetry (rank 0's own is
/// not included — the caller already holds it), the estimated clock
/// offset per world rank (index = rank, `[0] == 0`, unknown peers stay
/// 0), and human-readable notes for every peer that could not be
/// collected. Collection is best-effort by design: a dead or garbled
/// peer becomes a note, never an error.
#[derive(Clone, Debug, Default)]
pub struct PeerCollection {
    pub telemetry: Vec<RankTelemetry>,
    pub offsets_ns: Vec<i64>,
    pub notes: Vec<String>,
}

// ---------------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------------

/// Serialize one rank's telemetry into the versioned OBS payload.
pub fn encode_telemetry(t: &RankTelemetry) -> Vec<u8> {
    // Label table in first-use order (spans reference it by index).
    let mut labels: Vec<&'static str> = Vec::new();
    for s in &t.spans {
        if !labels.contains(&s.label) {
            labels.push(s.label);
        }
    }
    assert!(labels.len() <= MAX_LABELS, "span label table overflow");
    let mut out = Vec::with_capacity(
        HEADER_BYTES + 2 + labels.iter().map(|l| 2 + l.len()).sum::<usize>()
            + 4 + SPAN_BYTES * t.spans.len()
            + 4 + JOURNAL_BYTES * t.journal.len(),
    );
    out.extend_from_slice(&OBS_MAGIC);
    out.extend_from_slice(&OBS_VERSION.to_le_bytes());
    out.extend_from_slice(&(t.rank as u32).to_le_bytes());
    out.extend_from_slice(&t.clock_ns.to_le_bytes());
    out.extend_from_slice(&t.spans_dropped.to_le_bytes());
    out.extend_from_slice(&t.journal_dropped.to_le_bytes());
    out.extend_from_slice(&t.final_ratio.to_bits().to_le_bytes());
    out.extend_from_slice(&t.recoveries.to_le_bytes());
    out.extend_from_slice(&t.lost_intervals.to_le_bytes());
    out.extend_from_slice(&t.decreases.to_le_bytes());
    out.extend_from_slice(&t.increases.to_le_bytes());
    out.extend_from_slice(&(labels.len() as u16).to_le_bytes());
    for l in &labels {
        out.extend_from_slice(&(l.len() as u16).to_le_bytes());
        out.extend_from_slice(l.as_bytes());
    }
    out.extend_from_slice(&(t.spans.len() as u32).to_le_bytes());
    for s in &t.spans {
        let idx = labels.iter().position(|l| *l == s.label).unwrap() as u16;
        out.extend_from_slice(&idx.to_le_bytes());
        out.extend_from_slice(&s.id.to_le_bytes());
        out.extend_from_slice(&s.parent.to_le_bytes());
        out.extend_from_slice(&s.step.to_le_bytes());
        out.extend_from_slice(&s.start_ns.to_le_bytes());
        out.extend_from_slice(&s.end_ns.to_le_bytes());
    }
    out.extend_from_slice(&(t.journal.len() as u32).to_le_bytes());
    for r in &t.journal {
        out.push(match r.kind {
            DecisionKind::Ratio => 0,
            DecisionKind::Round => 1,
            DecisionKind::Membership => 2,
            DecisionKind::Straggler => 3,
            DecisionKind::Congestion => 4,
        });
        out.extend_from_slice(&(r.rank as u32).to_le_bytes());
        out.extend_from_slice(&r.step.to_le_bytes());
        out.extend_from_slice(&r.epoch.to_le_bytes());
        out.extend_from_slice(&(r.live as u32).to_le_bytes());
        out.extend_from_slice(&r.rtt_us.to_le_bytes());
        out.extend_from_slice(&r.payload_bytes.to_le_bytes());
        out.push(u8::from(r.lost) | (u8::from(r.phase_netsense) << 1));
        out.extend_from_slice(&r.old_ratio.to_bits().to_le_bytes());
        out.extend_from_slice(&r.new_ratio.to_bits().to_le_bytes());
        out.extend_from_slice(&r.predicted_wire_bytes.to_le_bytes());
        out.extend_from_slice(&r.recoveries.to_le_bytes());
        out.extend_from_slice(&r.dropped_stale.to_le_bytes());
        out.extend_from_slice(&r.dropped_garbage.to_le_bytes());
    }
    out
}

/// Byte cursor with named-error take primitives — every read is
/// length-checked, so a truncated payload fails with "truncated OBS
/// payload" at the exact shortfall instead of panicking.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.at < n {
            return Err(anyhow!(
                "truncated OBS payload: need {n} bytes at offset {}, have {}",
                self.at,
                self.buf.len() - self.at
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }
}

/// Decode an OBS payload. Malformed input — short, lying counts, bad
/// magic, unknown version or record kind, non-UTF-8 labels, trailing
/// bytes — returns a named `Err`; the function never panics and never
/// allocates more than the input length justifies.
pub fn decode_telemetry(bytes: &[u8]) -> Result<RankTelemetry> {
    let mut c = Cursor { buf: bytes, at: 0 };
    let magic = c.take(4)?;
    if magic != OBS_MAGIC {
        return Err(anyhow!("bad OBS magic {magic:02x?}"));
    }
    let version = c.u16()?;
    if version != OBS_VERSION {
        return Err(anyhow!("unsupported OBS version {version} (have {OBS_VERSION})"));
    }
    let rank = c.u32()? as usize;
    let clock_ns = c.u64()?;
    let spans_dropped = c.u64()?;
    let journal_dropped = c.u64()?;
    let final_ratio = f64::from_bits(c.u64()?);
    let recoveries = c.u32()?;
    let lost_intervals = c.u32()?;
    let decreases = c.u32()?;
    let increases = c.u32()?;

    let n_labels = c.u16()? as usize;
    if n_labels > MAX_LABELS {
        return Err(anyhow!("OBS label count {n_labels} exceeds cap {MAX_LABELS}"));
    }
    let mut labels: Vec<&'static str> = Vec::with_capacity(n_labels);
    for _ in 0..n_labels {
        let len = c.u16()? as usize;
        if len > MAX_LABEL_LEN {
            return Err(anyhow!("OBS span label of {len} bytes exceeds cap {MAX_LABEL_LEN}"));
        }
        let raw = c.take(len)?;
        let s = std::str::from_utf8(raw).map_err(|_| anyhow!("invalid UTF-8 in span label"))?;
        labels.push(intern_label(s)?);
    }

    let n_spans = c.u32()? as usize;
    if n_spans > MAX_RECORDS || c.remaining() < n_spans.saturating_mul(SPAN_BYTES) {
        return Err(anyhow!(
            "truncated OBS payload: {n_spans} spans declared, {} bytes remain",
            c.remaining()
        ));
    }
    let mut spans = Vec::with_capacity(n_spans);
    for _ in 0..n_spans {
        let idx = c.u16()? as usize;
        let label = *labels
            .get(idx)
            .ok_or_else(|| anyhow!("span label index {idx} out of range ({n_labels} labels)"))?;
        spans.push(SpanRecord {
            rank,
            id: c.u64()?,
            parent: c.u64()?,
            label,
            step: c.u32()?,
            start_ns: c.u64()?,
            end_ns: c.u64()?,
        });
    }

    let n_journal = c.u32()? as usize;
    if n_journal > MAX_RECORDS || c.remaining() < n_journal.saturating_mul(JOURNAL_BYTES) {
        return Err(anyhow!(
            "truncated OBS payload: {n_journal} journal records declared, {} bytes remain",
            c.remaining()
        ));
    }
    let mut journal = Vec::with_capacity(n_journal);
    for _ in 0..n_journal {
        let kind = match c.u8()? {
            0 => DecisionKind::Ratio,
            1 => DecisionKind::Round,
            2 => DecisionKind::Membership,
            3 => DecisionKind::Straggler,
            4 => DecisionKind::Congestion,
            k => return Err(anyhow!("unknown journal record kind {k}")),
        };
        let r_rank = c.u32()? as usize;
        let step = c.u32()?;
        let epoch = c.u32()?;
        let live = c.u32()? as usize;
        let rtt_us = c.u64()?;
        let payload_bytes = c.u64()?;
        let flags = c.u8()?;
        journal.push(DecisionRecord {
            kind,
            rank: r_rank,
            step,
            epoch,
            live,
            rtt_us,
            payload_bytes,
            lost: flags & 1 != 0,
            phase_netsense: flags & 2 != 0,
            old_ratio: f64::from_bits(c.u64()?),
            new_ratio: f64::from_bits(c.u64()?),
            predicted_wire_bytes: c.u64()?,
            recoveries: c.u32()?,
            dropped_stale: c.u32()?,
            dropped_garbage: c.u32()?,
        });
    }

    if c.remaining() != 0 {
        return Err(anyhow!("trailing bytes after OBS payload: {}", c.remaining()));
    }
    Ok(RankTelemetry {
        rank,
        clock_ns,
        spans,
        spans_dropped,
        journal,
        journal_dropped,
        final_ratio,
        recoveries,
        lost_intervals,
        decreases,
        increases,
    })
}

// ---------------------------------------------------------------------------
// Gather protocol
// ---------------------------------------------------------------------------

/// Receive from `from` until an envelope of `want` arrives, discarding a
/// bounded number of stray frames (duplicated / reordered leftovers from
/// the last training round parse as `Data`/`Probe` and are skipped, as is
/// outright garbage). Returns the envelope body.
fn recv_kind(t: &mut dyn Transport, from: usize, want: FrameKind) -> Result<Vec<u8>> {
    for _ in 0..MAX_SKIPPED_FRAMES {
        let bytes = t.recv(from)?;
        match parse_envelope(&bytes) {
            Ok((kind, _, _, body)) if kind == want => return Ok(body.to_vec()),
            Ok(_) | Err(_) => continue,
        }
    }
    Err(anyhow!(
        "no {want:?} frame from rank {from} within {MAX_SKIPPED_FRAMES} frames"
    ))
}

/// Rank 0's side of the gather: for each live peer, run the Clock
/// ping/pong (offset estimate), then receive and decode its OBS payload.
/// Best-effort — a peer that times out, disconnects, or sends a malformed
/// payload becomes a note, and the gather moves on.
pub fn gather_at_rank0(
    t: &mut dyn Transport,
    origin: Instant,
    peers: &[usize],
    timeout: Duration,
) -> PeerCollection {
    let mut out = PeerCollection {
        offsets_ns: vec![0; t.group_size()],
        ..PeerCollection::default()
    };
    t.set_recv_timeout(timeout);
    for &r in peers {
        let t0 = origin.elapsed().as_nanos() as u64;
        let mut env = Vec::with_capacity(ENVELOPE_OVERHEAD + 8);
        write_envelope(FrameKind::Clock, 0, 0, &mut env);
        env.extend_from_slice(&t0.to_le_bytes());
        if let Err(e) = t.send(r, &env) {
            out.notes.push(format!("rank {r}: clock ping send failed: {e}"));
            continue;
        }
        let pong = match recv_kind(t, r, FrameKind::Clock) {
            Ok(b) => b,
            Err(e) => {
                out.notes.push(format!("rank {r}: no clock pong: {e}"));
                continue;
            }
        };
        let t2 = origin.elapsed().as_nanos() as u64;
        let Ok(peer_ns) = pong.as_slice().try_into().map(u64::from_le_bytes) else {
            out.notes.push(format!("rank {r}: clock pong body was {} bytes, want 8", pong.len()));
            continue;
        };
        let offset = estimate_offset(t0, peer_ns, t2);
        let payload = match recv_kind(t, r, FrameKind::Obs) {
            Ok(b) => b,
            Err(e) => {
                out.notes.push(format!("rank {r}: no OBS payload: {e}"));
                continue;
            }
        };
        match decode_telemetry(&payload) {
            Ok(telemetry) => {
                if telemetry.rank != r {
                    out.notes
                        .push(format!("rank {r}: OBS payload claims rank {}", telemetry.rank));
                    continue;
                }
                if let Some(slot) = out.offsets_ns.get_mut(r) {
                    *slot = offset;
                }
                out.telemetry.push(telemetry);
            }
            Err(e) => out.notes.push(format!("rank {r}: malformed OBS payload: {e:#}")),
        }
    }
    out
}

/// A peer's side of the gather: answer rank 0's Clock ping with this
/// rank's own origin-relative time, then ship the OBS payload.
pub fn respond_to_collector(
    t: &mut dyn Transport,
    origin: Instant,
    own: &RankTelemetry,
    timeout: Duration,
) -> Result<()> {
    t.set_recv_timeout(timeout);
    recv_kind(t, 0, FrameKind::Clock)?;
    let now = origin.elapsed().as_nanos() as u64;
    let mut env = Vec::with_capacity(ENVELOPE_OVERHEAD + 8);
    write_envelope(FrameKind::Clock, 0, 0, &mut env);
    env.extend_from_slice(&now.to_le_bytes());
    t.send(0, &env)?;
    let payload = encode_telemetry(own);
    let mut obs = Vec::with_capacity(ENVELOPE_OVERHEAD + payload.len());
    write_envelope(FrameKind::Obs, 0, 0, &mut obs);
    obs.extend_from_slice(&payload);
    t.send(0, &obs)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::LoopbackTransport;

    fn sample() -> RankTelemetry {
        RankTelemetry {
            rank: 2,
            clock_ns: 123_456_789,
            spans: vec![
                SpanRecord {
                    rank: 2,
                    id: 1,
                    parent: 0,
                    label: "step",
                    step: 0,
                    start_ns: 1_000,
                    end_ns: 9_000,
                },
                SpanRecord {
                    rank: 2,
                    id: 2,
                    parent: 1,
                    label: "round",
                    step: 0,
                    start_ns: 2_000,
                    end_ns: 8_000,
                },
                SpanRecord {
                    rank: 2,
                    id: 3,
                    parent: 2,
                    label: "decode",
                    step: 0,
                    start_ns: 3_000,
                    end_ns: 4_000,
                },
            ],
            spans_dropped: 7,
            journal: vec![
                DecisionRecord {
                    kind: DecisionKind::Ratio,
                    rank: 2,
                    step: 0,
                    epoch: 1,
                    live: 4,
                    rtt_us: 1500,
                    payload_bytes: 4096,
                    lost: true,
                    phase_netsense: true,
                    old_ratio: 0.5,
                    new_ratio: 0.25,
                    predicted_wire_bytes: 2048,
                    recoveries: 1,
                    dropped_stale: 2,
                    dropped_garbage: 3,
                },
                DecisionRecord {
                    kind: DecisionKind::Membership,
                    rank: 2,
                    epoch: 2,
                    live: 3,
                    ..DecisionRecord::default()
                },
            ],
            journal_dropped: 1,
            final_ratio: 0.125,
            recoveries: 4,
            lost_intervals: 5,
            decreases: 6,
            increases: 9,
        }
    }

    #[test]
    fn obs_payload_roundtrips() {
        let t = sample();
        let bytes = encode_telemetry(&t);
        assert_eq!(decode_telemetry(&bytes).unwrap(), t);
    }

    #[test]
    fn obs_payload_interns_unknown_labels() {
        let mut t = sample();
        t.spans[0].label = "custom-phase";
        let bytes = encode_telemetry(&t);
        // The fuzz harness shares this process and may have filled the
        // bounded intern table with mutated labels — both outcomes are
        // in-contract, and which one we got must be stable.
        match decode_telemetry(&bytes) {
            Ok(back) => {
                assert_eq!(back.spans[0].label, "custom-phase");
                // A second decode reuses the interned copy.
                let again = decode_telemetry(&bytes).unwrap();
                assert!(std::ptr::eq(back.spans[0].label, again.spans[0].label));
            }
            Err(e) => {
                assert!(
                    format!("{e}").contains("too many distinct span labels"),
                    "unexpected decode error: {e}"
                );
            }
        }
    }

    #[test]
    fn obs_payload_truncation_at_every_prefix_is_a_named_err() {
        let bytes = encode_telemetry(&sample());
        for len in 0..bytes.len() {
            let err = decode_telemetry(&bytes[..len])
                .expect_err("every strict prefix must be rejected");
            assert!(!format!("{err:#}").is_empty());
        }
    }

    #[test]
    fn obs_payload_names_every_defect() {
        let good = encode_telemetry(&sample());

        let mut bad = good.clone();
        bad[0] = b'X';
        let e = decode_telemetry(&bad).unwrap_err();
        assert!(format!("{e}").contains("bad OBS magic"), "{e}");

        let mut bad = good.clone();
        bad[4] = 0xff;
        let e = decode_telemetry(&bad).unwrap_err();
        assert!(format!("{e}").contains("unsupported OBS version"), "{e}");

        let mut bad = good.clone();
        bad.push(0);
        let e = decode_telemetry(&bad).unwrap_err();
        assert!(format!("{e}").contains("trailing bytes"), "{e}");

        // Journal records sit at the tail: patch the first record's kind
        // byte to an unassigned value.
        let n_journal = sample().journal.len();
        let mut bad = good.clone();
        let at = bad.len() - n_journal * JOURNAL_BYTES;
        bad[at] = 9;
        let e = decode_telemetry(&bad).unwrap_err();
        assert!(format!("{e}").contains("unknown journal record kind 9"), "{e}");

        // A lying span count must fail by arithmetic, not by allocation:
        // patch n_spans (right after the label table) to a huge value.
        let labels_bytes: usize = 2 + ["step", "round", "decode"].iter().map(|l| 2 + l.len()).sum::<usize>();
        let mut bad = good;
        let at = HEADER_BYTES + labels_bytes;
        bad[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = decode_telemetry(&bad).unwrap_err();
        assert!(format!("{e}").contains("truncated OBS payload"), "{e}");
    }

    #[test]
    fn obs_gather_rejects_a_payload_claiming_the_wrong_rank() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut peer = mesh.pop().unwrap();
        let mut root = mesh.pop().unwrap();
        let origin = Instant::now();
        let own = sample(); // claims rank 2, arrives from rank 1
        let h = std::thread::spawn(move || {
            respond_to_collector(&mut peer, origin, &own, Duration::from_secs(5)).unwrap();
        });
        let got = gather_at_rank0(&mut root, origin, &[1], Duration::from_secs(5));
        h.join().unwrap();
        assert!(got.telemetry.is_empty());
        assert_eq!(got.notes.len(), 1);
        assert!(got.notes[0].contains("claims rank 2"), "{}", got.notes[0]);
        assert_eq!(got.offsets_ns, vec![0, 0]);
    }

    #[test]
    fn obs_gather_roundtrips_and_estimates_offsets_over_loopback() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut peer = mesh.pop().unwrap();
        let mut root = mesh.pop().unwrap();
        let origin = Instant::now();
        let mut own = sample();
        own.rank = 1;
        for s in &mut own.spans {
            s.rank = 1;
        }
        let own_for_peer = own.clone();
        let h = std::thread::spawn(move || {
            respond_to_collector(&mut peer, origin, &own_for_peer, Duration::from_secs(5)).unwrap();
        });
        let got = gather_at_rank0(&mut root, origin, &[1], Duration::from_secs(5));
        h.join().unwrap();
        assert!(got.notes.is_empty(), "{:?}", got.notes);
        assert_eq!(got.telemetry, vec![own]);
        // Shared origin → the estimated offset is bounded by the RTT of an
        // in-process channel; generous bound for loaded CI machines.
        assert!(got.offsets_ns[1].abs() < 1_000_000_000, "offset {}", got.offsets_ns[1]);
        assert_eq!(got.offsets_ns[0], 0);
    }

    #[test]
    fn obs_gather_notes_a_silent_peer_instead_of_failing() {
        let mut mesh = LoopbackTransport::mesh(2);
        drop(mesh.pop()); // peer never responds (channel closed)
        let mut root = mesh.pop().unwrap();
        let got = gather_at_rank0(
            &mut root,
            Instant::now(),
            &[1],
            Duration::from_millis(50),
        );
        assert!(got.telemetry.is_empty());
        assert_eq!(got.notes.len(), 1);
        assert!(got.notes[0].contains("rank 1"), "{}", got.notes[0]);
    }
}
