//! A tiny `std::net` scrape endpoint for live runs: every HTTP request
//! gets a fresh Prometheus-text snapshot of the global registry.
//!
//! One background thread, a nonblocking listener polled at ~20 Hz, and a
//! plain HTTP/1.0 response with `Connection: close` — enough for
//! `curl`/Prometheus, nothing more. The accept loop never touches the
//! hot path; it only *reads* the atomics the workers write.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::metrics::registry;

/// Handle to the background scrape thread. Dropping it (or calling
/// [`shutdown`](MetricsServer::shutdown)) stops the thread.
pub struct MetricsServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// serving snapshots of the global registry.
    pub fn start(addr: &str) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("netsense-metrics".into())
            .spawn(move || serve_loop(listener, &stop_flag))
            .expect("spawn metrics thread");
        Ok(MetricsServer {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_loop(listener: TcpListener, stop: &AtomicBool) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Best-effort: a scrape that fails mid-write is the
                // scraper's problem, not the run's.
                let _ = answer(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

fn answer(mut stream: std::net::TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    // Drain whatever request line/headers arrive; we answer any request
    // the same way, so parsing would be ceremony.
    let mut buf = [0u8; 1024];
    let _ = stream.read(&mut buf);
    let body = registry().prometheus();
    let head = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::hot;

    #[test]
    fn scrape_endpoint_serves_a_prometheus_snapshot() {
        // Touch the hot metrics so the snapshot is non-trivial.
        hot().rounds_total.inc();
        let mut server = MetricsServer::start("127.0.0.1:0").expect("bind ephemeral port");
        let addr = server.local_addr();
        let mut stream = std::net::TcpStream::connect(addr).expect("connect");
        stream
            .write_all(b"GET /metrics HTTP/1.0\r\n\r\n")
            .expect("send request");
        let mut response = String::new();
        stream.read_to_string(&mut response).expect("read response");
        assert!(response.starts_with("HTTP/1.0 200 OK"), "{response}");
        assert!(
            response.contains("text/plain; version=0.0.4"),
            "{response}"
        );
        assert!(response.contains("netsense_rounds_total"), "{response}");
        // Content-Length matches the body actually sent.
        let (head, body) = response.split_once("\r\n\r\n").expect("header/body split");
        let clen: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .expect("Content-Length header")
            .trim()
            .parse()
            .expect("numeric length");
        assert_eq!(clen, body.len());
        server.shutdown();
        // Idempotent shutdown + Drop after shutdown must not hang.
        server.shutdown();
    }

    /// Graceful shutdown releases the port: after `shutdown()` returns,
    /// the accept thread has joined and the exact same address can be
    /// rebound immediately — no lingering listener, no reliance on
    /// SO_REUSEADDR, no sleep.
    #[test]
    fn shutdown_joins_the_thread_and_releases_the_port() {
        let mut server = MetricsServer::start("127.0.0.1:0").expect("bind ephemeral port");
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0, "port 0 must resolve to a real port");
        server.shutdown();
        let rebound = TcpListener::bind(addr)
            .unwrap_or_else(|e| panic!("rebinding {addr} after shutdown failed: {e}"));
        assert_eq!(rebound.local_addr().expect("local addr").port(), addr.port());

        // A server dropped without an explicit shutdown releases too.
        let second = MetricsServer::start("127.0.0.1:0").expect("bind second");
        let addr2 = second.local_addr();
        drop(second);
        TcpListener::bind(addr2).expect("rebind after drop");
    }
}
