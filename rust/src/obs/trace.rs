//! Per-rank tracing spans in a preallocated ring buffer, exportable as
//! Chrome `trace_event` JSON (open at <https://ui.perfetto.dev>).
//!
//! A [`Tracer`] is thread-local by construction: each live worker owns
//! one, stamped with its rank, and all of them share the run's origin
//! [`Instant`] so their timelines align when the per-rank buffers are
//! merged into one trace file. Recording is two `Instant::now()` calls
//! and a few slot writes into storage allocated up front — no heap
//! traffic, gated by `telemetry_recording_is_allocation_free` in the
//! parent module. When the ring fills, the oldest finished span is
//! overwritten and a drop counter ticks; a trace is a window onto the
//! tail of a run, never a cause of memory growth.
//!
//! Span nesting comes from an internal stack: [`Tracer::start`] records
//! the current stack top as the new span's parent, so the live loop gets
//! `step ▸ compress / round ▸ decode` nesting for free without plumbing
//! parent ids through call sites.

use std::time::Instant;

use crate::util::json::{obj, Json};

/// Handle returned by [`Tracer::start`]; pass it back to [`Tracer::end`].
/// `SpanId(0)` is the no-op id (disabled tracer, or stack overflow) — safe
/// to `end`, records nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanId(pub u64);

impl SpanId {
    pub const NONE: SpanId = SpanId(0);
}

/// One finished span. Flat and `Copy` so the ring is a plain slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// Rank of the worker that recorded the span.
    pub rank: usize,
    /// Unique (per tracer) span id, starting at 1.
    pub id: u64,
    /// Id of the enclosing span, or 0 at top level.
    pub parent: u64,
    /// Static label ("step", "compress", "round", "decode", "recovery", …).
    pub label: &'static str,
    /// Training step the span belongs to.
    pub step: u32,
    /// Start offset from the run origin, nanoseconds.
    pub start_ns: u64,
    /// End offset from the run origin, nanoseconds (≥ `start_ns`).
    pub end_ns: u64,
}

/// An open span awaiting its `end` call.
#[derive(Debug, Clone, Copy)]
struct OpenSpan {
    id: u64,
    parent: u64,
    label: &'static str,
    step: u32,
    start_ns: u64,
}

/// Maximum nesting depth tracked; deeper `start`s return [`SpanId::NONE`].
const MAX_DEPTH: usize = 64;

/// Preallocated per-rank span recorder. See the module docs.
pub struct Tracer {
    rank: usize,
    origin: Instant,
    enabled: bool,
    next_id: u64,
    /// Stack of open spans (fixed capacity, no heap traffic past `new`).
    stack: Vec<OpenSpan>,
    /// Ring of finished spans.
    ring: Vec<SpanRecord>,
    /// Next ring slot to (over)write.
    head: usize,
    /// Total finished spans ever recorded (≥ `ring.len()`).
    recorded: u64,
    /// Finished spans overwritten because the ring was full.
    dropped: u64,
}

impl Tracer {
    /// A tracer for `rank` holding up to `capacity` finished spans,
    /// timestamped relative to `origin` (share one origin across ranks so
    /// merged timelines align). All storage is allocated here.
    pub fn new(rank: usize, capacity: usize, origin: Instant) -> Tracer {
        Tracer {
            rank,
            origin,
            enabled: capacity > 0,
            next_id: 1,
            stack: Vec::with_capacity(MAX_DEPTH),
            ring: Vec::with_capacity(capacity.max(1)),
            head: 0,
            recorded: 0,
            dropped: 0,
        }
    }

    /// A tracer whose `start`/`end` are no-ops — the disabled default for
    /// runs without `--trace-out`, so call sites don't branch.
    pub fn disabled() -> Tracer {
        let mut t = Tracer::new(0, 0, Instant::now());
        t.enabled = false;
        t
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    #[inline]
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    /// Open a span. Returns [`SpanId::NONE`] (a safe no-op handle) when
    /// disabled or nested deeper than `MAX_DEPTH`.
    #[inline]
    pub fn start(&mut self, label: &'static str, step: u32) -> SpanId {
        if !self.enabled || self.stack.len() >= MAX_DEPTH {
            return SpanId::NONE;
        }
        let id = self.next_id;
        self.next_id += 1;
        let parent = self.stack.last().map_or(0, |s| s.id);
        self.stack.push(OpenSpan {
            id,
            parent,
            label,
            step,
            start_ns: self.now_ns(),
        });
        SpanId(id)
    }

    /// Close a span. Pops the open stack down to (and including) `sp`, so
    /// a missed inner `end` truncates children instead of corrupting the
    /// nesting. No-op for [`SpanId::NONE`] or an id that's not open.
    #[inline]
    pub fn end(&mut self, sp: SpanId) {
        if !self.enabled || sp == SpanId::NONE {
            return;
        }
        let Some(pos) = self.stack.iter().rposition(|s| s.id == sp.0) else {
            return;
        };
        let end_ns = self.now_ns();
        while self.stack.len() > pos {
            let open = self.stack.pop().unwrap();
            self.push_record(SpanRecord {
                rank: self.rank,
                id: open.id,
                parent: open.parent,
                label: open.label,
                step: open.step,
                start_ns: open.start_ns,
                end_ns,
            });
        }
    }

    /// Record an already-finished span of known duration, ending *now* —
    /// for costs measured elsewhere and reported after the fact, like the
    /// wire-wait nanoseconds a [`Transport`] accumulated during a round
    /// ([`Transport::take_wire_wait_ns`]). The span is parented under the
    /// current stack top (so the live loop's `evloop` span nests inside
    /// `round`), and its start is clamped to the parent's start so it can
    /// never escape the enclosing span. No-op when disabled or `dur_ns`
    /// is 0.
    ///
    /// [`Transport`]: crate::transport::Transport
    /// [`Transport::take_wire_wait_ns`]: crate::transport::Transport::take_wire_wait_ns
    #[inline]
    pub fn record_backdated(&mut self, label: &'static str, step: u32, dur_ns: u64) {
        if !self.enabled || dur_ns == 0 {
            return;
        }
        let end_ns = self.now_ns();
        let mut start_ns = end_ns.saturating_sub(dur_ns);
        let parent = match self.stack.last() {
            Some(open) => {
                start_ns = start_ns.max(open.start_ns);
                open.id
            }
            None => 0,
        };
        let id = self.next_id;
        self.next_id += 1;
        self.push_record(SpanRecord {
            rank: self.rank,
            id,
            parent,
            label,
            step,
            start_ns,
            end_ns,
        });
    }

    #[inline]
    fn push_record(&mut self, rec: SpanRecord) {
        if self.ring.len() < self.ring.capacity() {
            self.ring.push(rec);
        } else {
            self.ring[self.head] = rec;
            self.dropped += 1;
        }
        self.head = (self.head + 1) % self.ring.capacity();
        self.recorded += 1;
    }

    /// Finished spans recorded over the tracer's lifetime.
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Finished spans overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Snapshot the surviving spans in recording order (oldest first).
    /// Cold path — allocates the output Vec.
    pub fn drain(&self) -> Vec<SpanRecord> {
        let n = self.ring.len();
        if n == 0 {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(n);
        // When full, `head` points at the oldest slot; when not yet full
        // the ring is already in order from 0.
        let start = if n == self.ring.capacity() { self.head } else { 0 };
        for i in 0..n {
            out.push(self.ring[(start + i) % n.max(1)]);
        }
        out
    }
}

/// Serialize spans (typically the merged `drain()`s of every rank) as
/// Chrome `trace_event` JSON — complete events (`"ph":"X"`) with
/// microsecond timestamps, `pid` 0, and `tid` = rank so Perfetto shows
/// one track per rank. `args` carries the step and span/parent ids for
/// cross-referencing against the decision journal.
pub fn chrome_trace_json(spans: &[SpanRecord]) -> String {
    chrome_trace_json_with_offsets(spans, &[])
}

/// [`chrome_trace_json`] plus clock-alignment provenance: when
/// `offsets_ns` is non-empty, a top-level `clockOffsetsNs` object maps
/// each rank to the estimated clock offset the merger subtracted from
/// its track ([`crate::obs::align::merge_aligned`] — the spans passed
/// here are already aligned; the metadata records what was applied, and
/// `scripts/check_trace.py` validates it). With an empty `offsets_ns`
/// the output is byte-identical to the pre-alignment format.
pub fn chrome_trace_json_with_offsets(spans: &[SpanRecord], offsets_ns: &[i64]) -> String {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            obj(vec![
                ("name", Json::from(s.label)),
                ("ph", Json::from("X")),
                ("pid", Json::from(0usize)),
                ("tid", Json::from(s.rank)),
                ("ts", Json::from(s.start_ns as f64 / 1000.0)),
                ("dur", Json::from((s.end_ns - s.start_ns) as f64 / 1000.0)),
                (
                    "args",
                    obj(vec![
                        ("step", Json::from(s.step as usize)),
                        ("id", Json::from(s.id)),
                        ("parent", Json::from(s.parent)),
                    ]),
                ),
            ])
        })
        .collect();
    let mut top = vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::from("ms")),
    ];
    if !offsets_ns.is_empty() {
        let mut map = std::collections::BTreeMap::new();
        for (rank, off) in offsets_ns.iter().enumerate() {
            map.insert(rank.to_string(), Json::from(*off));
        }
        top.push(("clockOffsetsNs", Json::Obj(map)));
    }
    obj(top).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_wait_ns(ns: u64) {
        let t = Instant::now();
        while (t.elapsed().as_nanos() as u64) < ns {
            std::hint::spin_loop();
        }
    }

    #[test]
    fn spans_nest_via_the_stack() {
        let mut t = Tracer::new(3, 16, Instant::now());
        let a = t.start("step", 7);
        let b = t.start("round", 7);
        let c = t.start("decode", 7);
        t.end(c);
        t.end(b);
        t.end(a);
        let spans = t.drain();
        assert_eq!(spans.len(), 3);
        // Recording order is close order: decode, round, step.
        assert_eq!(spans[0].label, "decode");
        assert_eq!(spans[1].label, "round");
        assert_eq!(spans[2].label, "step");
        // Parent chain: step(0) ← round ← decode.
        assert_eq!(spans[2].parent, 0);
        assert_eq!(spans[1].parent, spans[2].id);
        assert_eq!(spans[0].parent, spans[1].id);
        assert!(spans.iter().all(|s| s.rank == 3 && s.step == 7));
        // No negative durations and children bracket inside parents.
        for s in &spans {
            assert!(s.end_ns >= s.start_ns);
        }
        assert!(spans[0].start_ns >= spans[1].start_ns);
        assert!(spans[0].end_ns <= spans[2].end_ns);
    }

    #[test]
    fn end_closes_abandoned_children() {
        let mut t = Tracer::new(0, 16, Instant::now());
        let outer = t.start("step", 0);
        let _leaked = t.start("decode", 0);
        t.end(outer); // decode never ended explicitly
        let spans = t.drain();
        assert_eq!(spans.len(), 2, "abandoned child closed with its parent");
        assert!(spans.iter().any(|s| s.label == "decode"));
        // Ending again (or ending NONE) is a harmless no-op.
        t.end(outer);
        t.end(SpanId::NONE);
        assert_eq!(t.drain().len(), 2);
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut t = Tracer::new(0, 4, Instant::now());
        for step in 0..10u32 {
            let sp = t.start("step", step);
            t.end(sp);
        }
        assert_eq!(t.recorded(), 10);
        assert_eq!(t.dropped(), 6);
        let spans = t.drain();
        assert_eq!(spans.len(), 4);
        // Survivors are the newest four, oldest first.
        let steps: Vec<u32> = spans.iter().map(|s| s.step).collect();
        assert_eq!(steps, vec![6, 7, 8, 9]);
    }

    /// ISSUE satellite: the backdated `evloop` span nests under the open
    /// `round` span, clamps at the parent's start, and is a no-op when
    /// disabled or zero-length.
    #[test]
    fn backdated_span_nests_under_open_parent_and_clamps() {
        let mut t = Tracer::new(2, 16, Instant::now());
        let sp_round = t.start("round", 5);
        busy_wait_ns(50_000);
        // Plausible duration: nests inside `round`, ends "now".
        t.record_backdated("evloop", 5, 10_000);
        // Implausible duration (longer than the run): start clamps to the
        // parent's start rather than escaping it.
        t.record_backdated("evloop", 5, u64::MAX);
        t.end(sp_round);
        let spans = t.drain();
        assert_eq!(spans.len(), 3);
        let round = spans.iter().find(|s| s.label == "round").unwrap();
        let evs: Vec<_> = spans.iter().filter(|s| s.label == "evloop").collect();
        assert_eq!(evs.len(), 2);
        for ev in &evs {
            assert_eq!(ev.parent, round.id, "evloop parented under round");
            assert_eq!(ev.step, 5);
            assert!(ev.start_ns >= round.start_ns, "start clamped to parent");
            assert!(ev.end_ns <= round.end_ns, "ends before parent closes");
            assert!(ev.end_ns >= ev.start_ns);
        }
        // Zero duration records nothing; top-level backdating parents at 0.
        let before = t.recorded();
        t.record_backdated("evloop", 6, 0);
        assert_eq!(t.recorded(), before);
        t.record_backdated("evloop", 6, 1_000);
        assert_eq!(t.drain().last().unwrap().parent, 0);
        // Disabled tracer: no-op.
        let mut d = Tracer::disabled();
        d.record_backdated("evloop", 0, 1_000);
        assert_eq!(d.recorded(), 0);
    }

    #[test]
    fn disabled_tracer_is_a_noop() {
        let mut t = Tracer::disabled();
        let sp = t.start("step", 0);
        assert_eq!(sp, SpanId::NONE);
        t.end(sp);
        assert_eq!(t.recorded(), 0);
        assert!(t.drain().is_empty());
        assert_eq!(chrome_trace_json(&t.drain()), r#"{"displayTimeUnit":"ms","traceEvents":[]}"#);
    }

    #[test]
    fn chrome_trace_offsets_metadata_is_optional_and_typed() {
        // Empty offsets → byte-identical to the historical format.
        assert_eq!(
            chrome_trace_json_with_offsets(&[], &[]),
            r#"{"displayTimeUnit":"ms","traceEvents":[]}"#
        );
        let json = chrome_trace_json_with_offsets(&[], &[0, -1_500, 2_000]);
        let doc = crate::util::json::Json::parse(&json).expect("trace JSON parses");
        let offs = doc.get("clockOffsetsNs").expect("clockOffsetsNs present");
        assert_eq!(offs.get("0").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(offs.get("1").and_then(|v| v.as_f64()), Some(-1_500.0));
        assert_eq!(offs.get("2").and_then(|v| v.as_f64()), Some(2_000.0));
    }

    /// ISSUE satellite: Chrome-trace JSON well-formedness — parses with
    /// the in-repo JSON parser, spans nest, no negative durations.
    #[test]
    fn chrome_trace_json_is_well_formed() {
        let origin = Instant::now();
        let mut t = Tracer::new(1, 64, origin);
        for step in 0..3u32 {
            let sp_step = t.start("step", step);
            let sp_r = t.start("round", step);
            let sp_d = t.start("decode", step);
            busy_wait_ns(2_000); // ≥ 1 µs so ts/dur are distinguishable
            t.end(sp_d);
            t.end(sp_r);
            t.end(sp_step);
        }
        let spans = t.drain();
        let json = chrome_trace_json(&spans);
        let doc = crate::util::json::Json::parse(&json).expect("trace JSON parses");
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(events.len(), spans.len());
        // Index events by span id to check nesting on the JSON side.
        let mut by_id: std::collections::BTreeMap<u64, (f64, f64, u64)> =
            std::collections::BTreeMap::new();
        for ev in events {
            assert_eq!(ev.get("ph").and_then(|v| v.as_str()), Some("X"));
            assert_eq!(ev.get("tid").and_then(|v| v.as_f64()), Some(1.0));
            let ts = ev.get("ts").and_then(|v| v.as_f64()).unwrap();
            let dur = ev.get("dur").and_then(|v| v.as_f64()).unwrap();
            assert!(ts >= 0.0 && dur >= 0.0, "negative ts/dur in {ev:?}");
            let args = ev.get("args").unwrap();
            let id = args.get("id").and_then(|v| v.as_f64()).unwrap() as u64;
            let parent = args.get("parent").and_then(|v| v.as_f64()).unwrap() as u64;
            by_id.insert(id, (ts, dur, parent));
        }
        for (id, &(ts, dur, parent)) in &by_id {
            if parent == 0 {
                continue;
            }
            let &(pts, pdur, _) = by_id
                .get(&parent)
                .unwrap_or_else(|| panic!("span {id} orphaned: parent {parent} missing"));
            assert!(
                ts >= pts && ts + dur <= pts + pdur + 1e-6,
                "span {id} [{ts},{}] escapes parent {parent} [{pts},{}]",
                ts + dur,
                pts + pdur
            );
        }
    }
}
