//! Clock alignment for multi-process traces (DESIGN.md §3.12).
//!
//! Every rank's [`Tracer`](crate::obs::trace::Tracer) stamps spans
//! relative to its own `Instant` origin. The in-process loopback backend
//! shares one origin, so its merged traces align for free — but separate
//! TCP processes each pick their own origin, and the raw merge shears the
//! tracks apart by the origin skew. The collection handshake
//! ([`crate::obs::collect`]) measures that skew per peer with the NTP
//! midpoint method: rank 0 notes `t0`, the peer answers with its own
//! clock `p`, rank 0 notes `t2`, and under the symmetric-delay assumption
//! the peer's clock read true time `(t0 + t2) / 2`, so
//!
//! ```text
//! offset = p − (t0 + t2) / 2        (positive ⇒ peer's clock runs ahead)
//! aligned_peer_time = local_peer_time − offset
//! ```
//!
//! [`merge_aligned`] applies those offsets when stitching per-rank rings
//! into one timeline, then shifts the whole trace uniformly so no
//! timestamp goes negative (Chrome's trace viewer clips negative `ts`).
//! With all-zero offsets it degrades to exactly the old shared-origin
//! merge (sort by start time, rank, id).
//!
//! ```
//! use netsenseml::obs::align::estimate_offset;
//!
//! // Peer answered 1100 between our 100 and 300 → its clock runs 900 ahead.
//! assert_eq!(estimate_offset(100, 1_100, 300), 900);
//! ```

use crate::obs::trace::SpanRecord;

/// NTP midpoint clock-offset estimate, in nanoseconds: `peer_ns` is the
/// peer's clock sampled between our `t0_ns` and `t2_ns`. Positive means
/// the peer's clock (origin) runs ahead of ours. `i128` internally —
/// origin-relative u64 nanoseconds can exceed `i64` when summed.
pub fn estimate_offset(t0_ns: u64, peer_ns: u64, t2_ns: u64) -> i64 {
    let midpoint = (t0_ns as i128 + t2_ns as i128) / 2;
    (peer_ns as i128 - midpoint) as i64
}

/// Merge per-rank span rings into one timeline, subtracting each rank's
/// estimated clock offset (`offsets_ns[rank]`, missing ranks treated as
/// 0), then uniformly shifting so the earliest start is non-negative.
/// Output is sorted by `(start_ns, rank, id)` — the same order the
/// shared-origin merge produced, which this degrades to when every
/// offset is zero.
pub fn merge_aligned(per_rank: &[Vec<SpanRecord>], offsets_ns: &[i64]) -> Vec<SpanRecord> {
    let mut aligned: Vec<(i128, i128, SpanRecord)> = Vec::new();
    for spans in per_rank {
        for s in spans {
            let off = offsets_ns.get(s.rank).copied().unwrap_or(0) as i128;
            aligned.push((s.start_ns as i128 - off, s.end_ns as i128 - off, *s));
        }
    }
    let min_start = aligned.iter().map(|(s, _, _)| *s).min().unwrap_or(0);
    let shift = (-min_start).max(0);
    let mut out: Vec<SpanRecord> = aligned
        .into_iter()
        .map(|(start, end, mut s)| {
            s.start_ns = (start + shift) as u64;
            s.end_ns = (end + shift) as u64;
            s
        })
        .collect();
    out.sort_by_key(|s| (s.start_ns, s.rank, s.id));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Tracer;
    use std::time::{Duration, Instant};

    #[test]
    fn obs_offset_estimate_is_the_midpoint_residual() {
        assert_eq!(estimate_offset(100, 1_100, 300), 900);
        assert_eq!(estimate_offset(1_000, 200, 1_200), -900); // peer behind
        assert_eq!(estimate_offset(500, 500, 500), 0);
        // Sums beyond i64 territory must not overflow.
        let big = u64::MAX / 2;
        assert_eq!(estimate_offset(big, big, big), 0);
    }

    #[test]
    fn obs_merge_with_zero_offsets_is_the_plain_sorted_merge() {
        let a = SpanRecord {
            rank: 0,
            id: 1,
            parent: 0,
            label: "step",
            step: 0,
            start_ns: 5_000,
            end_ns: 9_000,
        };
        let b = SpanRecord {
            rank: 1,
            id: 1,
            parent: 0,
            label: "step",
            step: 0,
            start_ns: 4_000,
            end_ns: 8_000,
        };
        let merged = merge_aligned(&[vec![a], vec![b]], &[0, 0]);
        assert_eq!(merged.len(), 2);
        assert_eq!((merged[0].rank, merged[0].start_ns), (1, 4_000));
        assert_eq!((merged[1].rank, merged[1].start_ns), (0, 5_000));
    }

    #[test]
    fn obs_merge_shifts_uniformly_when_alignment_goes_negative() {
        let s = SpanRecord {
            rank: 1,
            id: 1,
            parent: 0,
            label: "step",
            step: 0,
            start_ns: 1_000,
            end_ns: 2_000,
        };
        // Offset larger than the local timestamp: aligned start would be
        // -9_000; the uniform shift keeps durations and brings it to 0.
        let merged = merge_aligned(&[vec![s]], &[0, 10_000]);
        assert_eq!(merged[0].start_ns, 0);
        assert_eq!(merged[0].end_ns, 1_000);
    }

    /// The satellite regression: two tracers with deliberately skewed
    /// origins (rank 1's origin set 10 ms in the past, so its raw
    /// timestamps run 10 ms hot) merge into a monotonic timeline once the
    /// known offset is applied — and visibly shear without it.
    #[test]
    fn obs_skewed_tracer_origins_merge_monotonic_after_alignment() {
        const SKEW: Duration = Duration::from_millis(10);
        let origin_a = Instant::now();
        let Some(origin_b) = origin_a.checked_sub(SKEW) else {
            return; // clock too close to boot to synthesize the skew
        };
        let mut ta = Tracer::new(0, 16, origin_a);
        let mut tb = Tracer::new(1, 16, origin_b);

        let sa = ta.start("step", 0);
        std::thread::sleep(Duration::from_millis(1));
        ta.end(sa);
        // Rank 1 works strictly *after* rank 0 in real time...
        let sb = tb.start("step", 0);
        std::thread::sleep(Duration::from_millis(1));
        tb.end(sb);

        let (a, b) = (ta.drain(), tb.drain());
        // ...yet unaligned, rank 1's span appears ~10 ms later than the
        // real gap (origin skew leaks into the timeline).
        let raw_gap = b[0].start_ns as i128 - a[0].end_ns as i128;
        assert!(raw_gap > 8_000_000, "raw gap {raw_gap} ns should carry the 10 ms skew");

        let merged = merge_aligned(&[a.clone(), b.clone()], &[0, SKEW.as_nanos() as i64]);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].rank, 0, "aligned order must follow real time");
        assert_eq!(merged[1].rank, 1);
        let aligned_gap = merged[1].start_ns as i128 - merged[0].end_ns as i128;
        assert!(
            aligned_gap >= 0 && aligned_gap < 8_000_000,
            "aligned gap {aligned_gap} ns should be the real sub-ms gap, not the skew"
        );
        // Alignment preserves every duration bit-exactly.
        assert_eq!(merged[0].end_ns - merged[0].start_ns, a[0].end_ns - a[0].start_ns);
        assert_eq!(merged[1].end_ns - merged[1].start_ns, b[0].end_ns - b[0].start_ns);
    }
}
