//! Integration: the full three-layer stack — AOT artifacts (JAX/Pallas) →
//! PJRT runtime → DDP coordinator over the simulated network.
//!
//! These tests require `make artifacts` to have run; they are skipped (with
//! a note) when `artifacts/manifest.json` is absent so `cargo test` stays
//! green in a fresh checkout.

use netsenseml::coordinator::{RealTrainConfig, RealTrainer, SyncStrategy};
use netsenseml::netsim::schedule::mbps;
use netsenseml::netsim::topology::StarTopology;
use netsenseml::netsim::{NetSim, SimTime};
use netsenseml::runtime::ModelRuntime;
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature (no PJRT runtime)");
        return None;
    }
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn sim(n: usize, bw_mbps: f64) -> NetSim {
    NetSim::quiet(StarTopology::constant(
        n,
        mbps(bw_mbps),
        SimTime::from_millis(10),
    ))
}

#[test]
fn runtime_loads_and_executes_mlp() {
    let Some(dir) = artifact_dir() else { return };
    let rt = ModelRuntime::load(&dir, "mlp").expect("load mlp");
    assert_eq!(rt.platform().to_lowercase(), "cpu");
    let state = rt.init_state().unwrap();
    assert_eq!(state.total_params(), rt.manifest.total_params);

    // One grad_step on a deterministic batch.
    let mm = &rt.manifest;
    let x = vec![0.1f32; mm.x_len()];
    let y: Vec<f32> = (0..mm.batch).map(|i| (i % mm.n_classes) as f32).collect();
    let out = rt.grad_step(&state, &x, &y).unwrap();
    assert_eq!(out.flat_grad.len(), mm.total_params);
    assert!(out.loss.is_finite() && out.loss > 0.0);
    // Initial loss ≈ ln(100) for 100-way softmax.
    assert!((out.loss - (100f32).ln()).abs() < 1.0, "loss {}", out.loss);
    assert!(out.flat_grad.iter().any(|&g| g != 0.0));

    // apply_update moves the parameters in the right direction.
    let mut state2 = state.clone();
    rt.apply_update(&mut state2, &out.flat_grad, 0.05).unwrap();
    let before = state.flat_params();
    let after = state2.flat_params();
    // With a constant input batch many ReLU units are dead (zero grads),
    // so expect a substantial minority of parameters to move, not all.
    let moved = before
        .iter()
        .zip(&after)
        .filter(|(a, b)| a != b)
        .count();
    assert!(moved > before.len() / 10, "only {moved} params moved");
    // Update rule check on a sample: p' = p - lr·g (zero momentum start).
    for i in (0..before.len()).step_by(100_001) {
        let want = before[i] - 0.05 * out.flat_grad[i];
        assert!((after[i] - want).abs() < 1e-5, "elem {i}");
    }
}

#[test]
fn apply_update_matches_manual_momentum_two_steps() {
    let Some(dir) = artifact_dir() else { return };
    let rt = ModelRuntime::load(&dir, "mlp").expect("load mlp");
    let mut state = rt.init_state().unwrap();
    let n = rt.manifest.total_params;
    let g1: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) * 1e-3).collect();
    let g2: Vec<f32> = (0..n).map(|i| ((i % 5) as f32 - 2.0) * 1e-3).collect();
    let p0 = state.flat_params();
    rt.apply_update(&mut state, &g1, 0.1).unwrap();
    rt.apply_update(&mut state, &g2, 0.1).unwrap();
    let p2 = state.flat_params();
    let mu = rt.manifest.momentum as f32;
    for i in (0..n).step_by(123_457) {
        let m1 = g1[i];
        let p1 = p0[i] - 0.1 * m1;
        let m2 = mu * m1 + g2[i];
        let want = p1 - 0.1 * m2;
        assert!(
            (p2[i] - want).abs() < 1e-5,
            "elem {i}: {} vs {want}",
            p2[i]
        );
    }
}

#[test]
fn real_ddp_training_reduces_loss_on_all_strategies() {
    let Some(dir) = artifact_dir() else { return };
    let rt = ModelRuntime::load(&dir, "mlp").expect("load mlp");
    for strategy in [
        SyncStrategy::AllReduce,
        SyncStrategy::TopK(0.1),
        SyncStrategy::NetSense,
    ] {
        let config = RealTrainConfig {
            n_workers: 4,
            strategy: strategy.clone(),
            steps: 12,
            lr: 0.05,
            eval_every: 6,
            seed: 3,
        };
        let mut trainer = RealTrainer::new(&rt, config).unwrap();
        let mut net = sim(4, 500.0);
        let log = trainer.train(&mut net).unwrap();
        assert_eq!(log.records.len(), 12);
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        assert!(
            last < first,
            "{}: loss did not decrease ({first} → {last})",
            strategy.label()
        );
        // Virtual time advanced (network was exercised).
        assert!(log.total_vtime() > 0.0);
        // Sparse strategies must have sent less than dense.
        if strategy != SyncStrategy::AllReduce {
            let dense = 4 * rt.manifest.total_params as u64;
            assert!(log.records.iter().all(|r| r.payload_bytes <= dense));
        }
    }
}

#[test]
fn worker_replicas_see_identical_aggregated_state() {
    // The DDP invariant the coordinator exploits: with identical init and
    // identical aggregated gradients, one state == N states. Verify the
    // mean gradient applied twice from the same inputs is deterministic.
    let Some(dir) = artifact_dir() else { return };
    let rt = ModelRuntime::load(&dir, "mlp").expect("load mlp");
    let run = || {
        let config = RealTrainConfig {
            n_workers: 2,
            strategy: SyncStrategy::NetSense,
            steps: 4,
            lr: 0.05,
            eval_every: 2,
            seed: 11,
        };
        let mut trainer = RealTrainer::new(&rt, config).unwrap();
        let mut net = sim(2, 300.0);
        trainer.train(&mut net).unwrap();
        trainer.state().flat_params()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "training is not deterministic");
}
