//! Integration: experiment harness end-to-end in fast mode — every paper
//! table/figure runner produces well-formed output with the paper's
//! qualitative orderings, and CSV outputs land where requested.

use netsenseml::experiments::scenario::RunOpts;
use netsenseml::experiments::{degrading, fig2, fig3, fluctuating, tables, tta};

fn opts_with_out(dir: &std::path::Path) -> RunOpts {
    RunOpts {
        fast: true,
        out_dir: Some(dir.to_path_buf()),
        seed: 42,
        n_workers: 8,
        fidelity_every: 0,
    }
}

#[test]
fn all_runners_produce_tables_and_csvs() {
    let dir = std::env::temp_dir().join("netsense_it_results");
    std::fs::create_dir_all(&dir).unwrap();
    let opts = opts_with_out(&dir);

    let (t1, _) = tables::table1(&opts);
    assert_eq!(t1.rows.len(), 9);
    assert!(dir.join("table1.csv").exists());

    let (f5, _) = tta::fig5(&opts);
    assert_eq!(f5.rows.len(), 9);
    assert!(dir.join("fig5_200Mbps.csv").exists());

    let (f7, _) = degrading::fig7(&opts);
    assert_eq!(f7.rows.len(), 10);
    assert!(dir.join("fig7.csv").exists());

    let (f8, _) = fluctuating::fig8(&opts);
    assert_eq!(f8.rows.len(), 3);
    assert!(dir.join("fig8.csv").exists());

    let (f2t, _) = fig2::fig2(&opts);
    assert!(f2t.rows.len() >= 10);
    assert!(dir.join("fig2.csv").exists());

    let (f3t, _) = fig3::fig3(&opts);
    assert_eq!(f3t.rows.len(), 14);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn headline_speedup_band_holds_at_200mbps() {
    // The paper's claim: 1.55–9.84× throughput over the baselines in
    // bandwidth-constrained conditions. Verify in fast mode at 200 Mbps.
    let opts = RunOpts {
        fast: true,
        out_dir: None,
        seed: 1,
        n_workers: 8,
        fidelity_every: 0,
    };
    let (_, cells) = tables::table1(&opts);
    let at_200: Vec<_> = cells.iter().filter(|c| c.bw_label == "200Mbps").collect();
    let ns = at_200.iter().find(|c| c.method == "NetSenseML").unwrap();
    let ar = at_200.iter().find(|c| c.method == "AllReduce").unwrap();
    let tk = at_200.iter().find(|c| c.method == "TopK-0.1").unwrap();
    let speedup_ar = ns.throughput / ar.throughput;
    let speedup_tk = ns.throughput / tk.throughput;
    assert!(
        speedup_ar >= 1.55 && speedup_ar <= 25.0,
        "vs AllReduce: {speedup_ar:.2}x"
    );
    assert!(
        speedup_tk >= 1.55 && speedup_tk <= 25.0,
        "vs TopK: {speedup_tk:.2}x"
    );
}

#[test]
fn seeds_change_noise_not_orderings() {
    for seed in [7, 99] {
        let opts = RunOpts {
            fast: true,
            out_dir: None,
            seed,
            n_workers: 8,
            fidelity_every: 0,
        };
        let (_, cells) = tables::table1(&opts);
        for chunk in cells.chunks(3) {
            assert!(chunk[0].throughput > chunk[1].throughput, "seed {seed}");
            assert!(chunk[0].throughput > chunk[2].throughput, "seed {seed}");
        }
    }
}
