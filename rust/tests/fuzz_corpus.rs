//! Regression-corpus replay: every committed crasher/rejecter under
//! `rust/tests/corpus/` must keep mapping to its pinned outcome.
//!
//! The corpus is the fuzzing subsystem's long-term memory: each entry is
//! a small wire blob (frame, COO payload, epoch envelope, or checkpoint)
//! that once exercised an interesting decoder path, pinned in
//! `MANIFEST.tsv` to either `ok` (must decode, and re-canonicalize where
//! the surface defines it) or a named-error substring (must be rejected
//! with exactly that named error). A refactor that changes an error
//! message, starts accepting a malformed input, or starts rejecting a
//! valid one fails here — loudly, with the entry's name.
//!
//! Replays go through [`netsenseml::testing::fuzz::probe_surface`], the
//! same harness the fuzz tests drive, so the full PR-5 contract (no
//! panic, no OOB scatter, accumulator untouched on `Err`,
//! fused-vs-staged agreement) is asserted on every entry too.

use std::path::Path;

#[test]
fn corpus_replays_to_pinned_outcomes() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/corpus");
    let manifest = std::fs::read_to_string(dir.join("MANIFEST.tsv"))
        .expect("rust/tests/corpus/MANIFEST.tsv must exist");
    let mut n_entries = 0usize;
    for (lineno, line) in manifest.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut cols = line.split('\t');
        let (file, surface, expected) = match (cols.next(), cols.next(), cols.next()) {
            (Some(f), Some(s), Some(e)) => (f, s, e),
            _ => panic!("MANIFEST.tsv line {}: want `file\\tsurface\\texpected`", lineno + 1),
        };
        let bytes = std::fs::read(dir.join(file))
            .unwrap_or_else(|e| panic!("{file}: unreadable corpus entry: {e}"));
        let verdict = netsenseml::testing::fuzz::probe_surface(surface, &bytes)
            .unwrap_or_else(|| panic!("{file}: unknown surface `{surface}`"));
        match (expected, verdict) {
            ("ok", Ok(())) => {}
            ("ok", Err(e)) => panic!("{file}: pinned ok, now rejected: {e}"),
            (pin, Ok(())) => panic!("{file}: pinned error `{pin}`, now accepted"),
            (pin, Err(e)) => assert!(
                e.contains(pin),
                "{file}: pinned error `{pin}`, got `{e}`"
            ),
        }
        n_entries += 1;
    }
    assert!(
        n_entries >= 15,
        "corpus shrank to {n_entries} entries — it only ever grows"
    );
}
